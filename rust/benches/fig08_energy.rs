//! Fig 8 — energy saving over the V100 GPU.

use switchblade::coordinator::{Caches, Harness};
use switchblade::util::bench;

fn main() {
    let scale = 8;
    let h = Harness { scale, ..Default::default() };
    let cache = Caches::new(scale);
    let rows = h.eval_all(&cache);
    let stats = bench::bench(1, 5, || h.fig08(&rows));
    bench::report("fig08/render", &stats);
    h.fig08(&rows).print();
}
