//! Fig 10 — overall hardware utilisation with SLMT on (3 sThreads) vs off (1).

use switchblade::coordinator::{Caches, Harness};
use switchblade::util::bench;

fn main() {
    let scale = 8;
    let h = Harness { scale, ..Default::default() };
    let cache = Caches::new(scale);
    let stats = bench::bench(0, 1, || h.fig10(&cache));
    bench::report("fig10/sweep(1v3 sThreads)", &stats);
    h.fig10(&cache).print();
}
