//! Fig 13 — data reuse from enlarging the DstBuffer (8 MB → 13 MB).

use switchblade::coordinator::{Caches, Harness};
use switchblade::util::bench;

fn main() {
    let scale = 8;
    let h = Harness { scale, ..Default::default() };
    let cache = Caches::new(scale);
    let stats = bench::bench(0, 1, || h.fig13(&cache));
    bench::report("fig13/db_sweep", &stats);
    h.fig13(&cache).print();
    h.tbl05().print();
}
