//! Fig 9 — off-chip data transfer with PLOF vs the GPU op-by-op paradigm.

use switchblade::coordinator::{Caches, Harness};
use switchblade::util::bench;

fn main() {
    let scale = 8;
    let h = Harness { scale, ..Default::default() };
    let cache = Caches::new(scale);
    let rows = h.eval_all(&cache);
    let stats = bench::bench(1, 5, || h.fig09(&rows));
    bench::report("fig09/render", &stats);
    h.fig09(&rows).print();
}
