//! DSE smoke bench — a budgeted `tune` sweep on GCN/ak2010, then the same
//! sweep again on warm caches (the second run should be dominated by
//! simulation only: every graph/program/partition lookup hits).

use switchblade::dse::{tune, Caches, TuneOptions};
use switchblade::graph::datasets::Dataset;
use switchblade::ir::zoo::ModelZoo;
use switchblade::util::bench;

fn main() {
    let scale = 8;
    let caches = Caches::new(scale);
    let opts = TuneOptions {
        budget: 24,
        ..Default::default()
    };
    let gcn = ModelZoo::builtin().get("gcn").expect("builtin gcn");
    let cold = bench::bench(0, 1, || tune(&gcn, Dataset::Ak, &caches, &opts));
    bench::report("dse/tune(GCN,AK,24pts) cold", &cold);
    let warm = bench::bench(0, 1, || tune(&gcn, Dataset::Ak, &caches, &opts));
    bench::report("dse/tune(GCN,AK,24pts) warm", &warm);

    let r = tune(&gcn, Dataset::Ak, &caches, &opts);
    r.frontier_table().print();
    print!("{}", r.summary());
}
