//! Fig 7 — speedup over the V100 GPU across 4 models × 5 datasets.
//! Regenerates the figure series and times the harness (hand-rolled
//! harness; criterion is unavailable offline).

use switchblade::coordinator::{Caches, Harness};
use switchblade::util::bench;

fn main() {
    let scale = 8; // bench scale: fast but non-trivial
    let h = Harness { scale, ..Default::default() };
    let cache = Caches::new(scale);
    let stats = bench::bench(1, 3, || h.eval_all(&cache));
    bench::report("fig07/eval_all(4x5)", &stats);
    let rows = h.eval_all(&cache);
    h.fig07(&rows).print();
}
