//! Property-based invariants over random graphs, models and budgets
//! (hand-rolled Cases runner; proptest is unavailable offline).

use switchblade::compiler::compile;
use switchblade::exec::{reference, weights, Executor, Matrix};
use switchblade::graph::{generators, Csr, EdgeList};
use switchblade::ir::models::Model;
use switchblade::isa::Space;
use switchblade::partition::{partition_dsw, partition_fggp, PartitionConfig};
use switchblade::util::prop::Cases;
use switchblade::util::rng::Rng;

fn random_graph(rng: &mut Rng) -> Csr {
    match rng.gen_range(4) {
        0 => {
            let n = 1usize << rng.usize_in(4, 9);
            let e = rng.usize_in(n, 6 * n);
            Csr::from_edge_list(&generators::rmat(n, e, 0.57, 0.19, 0.19, rng.next_u64()))
        }
        1 => {
            let n = rng.usize_in(20, 400);
            let e = rng.usize_in(n / 2, 4 * n);
            Csr::from_edge_list(&generators::erdos_renyi(n, e, rng.next_u64()))
        }
        2 => {
            let r = rng.usize_in(3, 20);
            Csr::from_edge_list(&generators::mesh2d(r, rng.usize_in(3, 20), rng.bool(0.5)))
        }
        _ => {
            let n = rng.usize_in(10, 300);
            let m = rng.usize_in(1, 4.min(n - 1));
            Csr::from_edge_list(&generators::barabasi_albert(n, m, rng.next_u64()))
        }
    }
}

fn random_cfg(rng: &mut Rng, prog: &switchblade::isa::Program) -> PartitionConfig {
    PartitionConfig {
        shard_bytes: rng.gen_range(63 * 1024) + 1024,
        dst_bytes: rng.gen_range(255 * 1024) + 1024,
        dim_src: prog.dim_src.max(1),
        dim_edge: prog.dim_edge.max(1),
        dim_dst: prog.dim_dst.max(1),
        num_sthreads: rng.gen_range(4) as u32 + 1,
    }
}

#[test]
fn prop_partitions_valid_and_cover_all_edges() {
    Cases::new(40).run("partition-validity", |rng| {
        let g = random_graph(rng);
        let prog = compile(&Model::Gcn.build(1, 8, 8, 8));
        let cfg = random_cfg(rng, &prog);
        let p = if rng.bool(0.5) {
            partition_fggp(&g, cfg)
        } else {
            partition_dsw(&g, cfg)
        };
        p.validate().expect("partition invariants");
    });
}

#[test]
fn prop_fggp_never_loads_more_than_dsw() {
    Cases::new(25).run("fggp-traffic-dominance", |rng| {
        let g = random_graph(rng);
        let prog = compile(&Model::Gcn.build(1, 8, 8, 8));
        let cfg = random_cfg(rng, &prog);
        let loaded = |p: &switchblade::partition::Partitions| -> u64 {
            p.shards.iter().map(|s| s.loaded_bytes(&p.config)).sum()
        };
        let f = loaded(&partition_fggp(&g, cfg));
        let d = loaded(&partition_dsw(&g, cfg));
        assert!(f <= d, "FGGP loaded {f} > DSW loaded {d}");
    });
}

#[test]
fn prop_compiled_equals_reference() {
    Cases::new(16).run("compile-exec-vs-oracle", |rng| {
        let g = random_graph(rng);
        let model = Model::ALL[rng.usize_in(0, 4)];
        let dim = [1u32, 2, 4, 8][rng.usize_in(0, 4)];
        let layers = rng.gen_range(2) as u32 + 1;
        let ir = model.build(layers, dim, dim, dim);
        let prog = compile(&ir);
        let cfg = random_cfg(rng, &prog);
        let p = if rng.bool(0.5) {
            partition_fggp(&g, cfg)
        } else {
            partition_dsw(&g, cfg)
        };
        let x = weights::init_features(rng.next_u64(), g.num_vertices(), dim as usize);
        let mut deg = Matrix::zeros(g.num_vertices(), 1);
        for v in 0..g.num_vertices() {
            deg.set(v, 0, g.in_degree(v as u32) as f32);
        }
        let got = Executor::new(&prog, &p).run(&x, &deg);
        let want = reference::evaluate(&ir, &g, &x);
        assert!(
            got.allclose(&want, 1e-3, 1e-4),
            "{} x{layers} d{dim} on {} vertices ({:?}): {}",
            model.name(),
            g.num_vertices(),
            p.method,
            got.max_abs_diff(&want)
        );
    });
}

#[test]
fn prop_simulation_deterministic_and_bounded() {
    Cases::new(12).run("sim-sanity", |rng| {
        use switchblade::sim::{simulate, AcceleratorConfig};
        let g = random_graph(rng);
        let model = Model::ALL[rng.usize_in(0, 4)];
        let prog = compile(&model.build(2, 16, 16, 16));
        let accel = AcceleratorConfig::switchblade()
            .with_sthreads(rng.gen_range(5) as u32 + 1);
        let parts = partition_fggp(&g, accel.partition_config(&prog));
        let a = simulate(&prog, &parts, &accel);
        let b = simulate(&prog, &parts, &accel);
        assert_eq!(a.cycles.to_bits(), b.cycles.to_bits(), "determinism");
        assert!(a.vu_busy <= a.cycles + 1.0);
        assert!(a.mu_busy <= a.cycles + 1.0);
        assert!(a.dram_busy <= a.cycles + 1.0);
        assert!(a.traffic.total() > 0);
    });
}

#[test]
fn prop_liveness_merging_preserves_budgets() {
    // dim_src/dim_edge after merging never exceed the naive sum of all
    // S/E symbol widths, and every instruction references table entries.
    Cases::new(20).run("liveness-consistency", |rng| {
        let model = Model::ALL[rng.usize_in(0, 4)];
        let dim = [4u32, 8, 16][rng.usize_in(0, 3)];
        let prog = compile(&model.build(2, dim, dim, dim));
        for g in &prog.groups {
            for i in g.all_instrs() {
                for s in i.def().into_iter().chain(i.uses()) {
                    assert!(
                        prog.symbols.get(s).is_some(),
                        "{}: instr references unknown symbol {s}",
                        prog.model_name
                    );
                }
            }
        }
        assert!(prog.dim_src <= prog.symbols.total_cols(Space::S));
        assert!(prog.dim_edge <= prog.symbols.total_cols(Space::E));
    });
}
