//! Serving-engine integration: the persistent engine must be a pure
//! performance layer over the executor — bit-identical outputs to a
//! direct (cold) run for any zoo model *and* an out-of-zoo spec file,
//! micro-batched or not; bounded queues must reject at capacity with a
//! typed error; warm steady state must add no new scratch-pool misses
//! or thread spawns; a poisoned request must fail alone instead of
//! taking the engine down; ticket lifecycle edges (an abandoned
//! ticket, a submit racing shutdown) must stay typed — never a hang.

use std::path::Path;
use std::sync::Arc;

use switchblade::coordinator::reference_run;
use switchblade::exec::Matrix;
use switchblade::graph::datasets::Dataset;
use switchblade::graph::Csr;
use switchblade::ir::spec::{ModelDims, ModelSpec};
use switchblade::ir::zoo::ModelZoo;
use switchblade::serve::{
    run_bench, BenchOptions, Engine, EngineConfig, Input, ServeError, SubmitOptions,
};

fn graph(scale: u32) -> Arc<Csr> {
    Arc::new(Dataset::Ak.load(scale))
}

/// The out-of-zoo spec the acceptance criteria name: cwd for
/// integration tests is `rust/`, so the example lives one level up.
fn gin() -> Arc<ModelSpec> {
    Arc::new(ModelSpec::from_file(Path::new("../examples/models/gin.gnn")).unwrap())
}

#[test]
fn engine_matches_direct_executor_bitwise() {
    let g = graph(8);
    let cfg = EngineConfig::default();
    let mut engine = Engine::new(cfg);
    let mut cases: Vec<(Arc<ModelSpec>, ModelDims)> = Vec::new();
    for name in ["gcn", "gat"] {
        let spec = ModelZoo::builtin().resolve(name).unwrap();
        cases.push((spec, ModelDims::uniform(2, 8)));
    }
    let gin = gin();
    let gin_dims = gin.dims();
    cases.push((gin, gin_dims));
    for (spec, dims) in &cases {
        let id = engine.register(spec, *dims, g.clone()).unwrap();
        let got = engine.submit_seeded(id, 42).unwrap().wait().unwrap();
        let ir = spec.build(*dims).unwrap();
        let want = reference_run(
            &ir,
            &g,
            &cfg.accel,
            cfg.method,
            cfg.workers,
            cfg.kernel,
            cfg.pipeline,
            42,
        );
        assert!(
            got.out.bits_eq(&want),
            "{}: engine output diverged from the direct executor run (max |delta| {})",
            spec.name(),
            got.out.max_abs_diff(&want)
        );
    }
}

#[test]
fn micro_batched_equals_one_at_a_time() {
    let g = graph(8);
    let spec = ModelZoo::builtin().resolve("gcn").unwrap();
    let dims = ModelDims::uniform(1, 8);

    // Batched: flood all requests in before waiting on any, so the
    // entry thread gets the chance to lift them out as bursts.
    let mut batched = Engine::new(EngineConfig {
        batch_max: 8,
        ..EngineConfig::default()
    });
    let id = batched.register(&spec, dims, g.clone()).unwrap();
    let tickets: Vec<_> = (0..8u64)
        .map(|s| batched.submit_seeded(id, s).unwrap())
        .collect();
    let outs: Vec<Matrix> = tickets
        .into_iter()
        .map(|t| t.wait().unwrap().out)
        .collect();

    // One at a time: batch cap 1 and a wait between submissions.
    let mut seq = Engine::new(EngineConfig {
        batch_max: 1,
        ..EngineConfig::default()
    });
    let id2 = seq.register(&spec, dims, g).unwrap();
    for (s, batched_out) in outs.iter().enumerate() {
        let r = seq.submit_seeded(id2, s as u64).unwrap().wait().unwrap();
        assert_eq!(r.batched, 1);
        assert!(
            r.out.bits_eq(batched_out),
            "request {s}: micro-batched output diverged from one-at-a-time"
        );
    }
}

#[test]
fn flooded_micro_batch_is_one_batched_run() {
    // The cross-request amortization pin at the serve layer: B requests
    // drained as one micro-batch go down as ONE batched executor run —
    // one partition walk for the whole batch (`EntryStats::batches`
    // counts exactly those runs; the exec-layer trace test pins one run
    // == one walk). Registration returns before the entry thread's
    // compile + partition + warm-up, so requests submitted immediately
    // after it queue up behind the warm-up and drain together.
    let g = graph(10);
    let spec = ModelZoo::builtin().resolve("gcn").unwrap();
    let mut engine = Engine::new(EngineConfig {
        batch_max: 8,
        ..EngineConfig::default()
    });
    let id = engine.register(&spec, ModelDims::uniform(1, 8), g).unwrap();
    // Mix the canonical entry point and a legacy wrapper: both feed the
    // same batched path.
    let tickets: Vec<_> = (0..6u64)
        .map(|s| {
            if s % 2 == 0 {
                engine
                    .submit_with(id, Input::Seeded(s), SubmitOptions::default())
                    .unwrap()
            } else {
                engine.submit_seeded(id, s).unwrap()
            }
        })
        .collect();
    for (s, t) in tickets.into_iter().enumerate() {
        let r = t.wait().unwrap();
        assert_eq!(r.seq, s as u64);
        assert_eq!(
            r.batched, 6,
            "request {s} did not ride the flooded 6-request micro-batch"
        );
    }
    let st = engine.stats(id).unwrap();
    assert_eq!(st.requests, 6);
    assert_eq!(
        st.batches, 1,
        "6 flooded requests must drive exactly one batched run (one partition walk)"
    );
    assert_eq!(st.max_batch, 6);
}

#[test]
fn poisoned_batch_member_fails_alone_in_one_batched_run() {
    // One NonFinite member of a batched run fails with its OWN seq while
    // its batch-mates succeed: lanes are column-disjoint in the stacked
    // run, so one request's inf never leaks into another's columns. The
    // BLOWUP spec computes exp(1e20 * x): negative features collapse to
    // exp(-inf) = 0 (finite), positive ones explode to +inf.
    let g = graph(8);
    let spec = ModelSpec::parse("blowup", BLOWUP).unwrap();
    let dims = spec.dims();
    let mut engine = Engine::new(EngineConfig {
        batch_max: 8,
        ..EngineConfig::default()
    });
    let id = engine.register(&spec, dims, g.clone()).unwrap();
    let n = g.num_vertices();
    let fill = |v: f32| {
        let mut m = Matrix::zeros(n, 4);
        for r in 0..n {
            for c in 0..4 {
                m.set(r, c, v);
            }
        }
        m
    };
    // Flood during warm-up so all three drain as one micro-batch:
    // healthy, poisoned, healthy.
    let t0 = engine
        .submit_with(id, Input::Features(fill(-1.0)), SubmitOptions::default())
        .unwrap();
    let t1 = engine
        .submit_with(id, Input::Features(fill(1.0)), SubmitOptions::default())
        .unwrap();
    let t2 = engine
        .submit_with(id, Input::Features(fill(-1.0)), SubmitOptions::default())
        .unwrap();
    let r0 = t0.wait().unwrap();
    assert_eq!((r0.seq, r0.batched), (0, 3));
    match t1.wait() {
        Err(ServeError::NonFinite { seq, .. }) => assert_eq!(seq, 1),
        other => panic!(
            "poisoned member should fail NonFinite with its own seq, got {:?}",
            other.map(|r| r.seq)
        ),
    }
    let r2 = t2.wait().unwrap();
    assert_eq!((r2.seq, r2.batched), (2, 3));
    let st = engine.stats(id).unwrap();
    assert_eq!(st.batches, 1, "the three requests must share one batched run");
    assert_eq!(st.requests, 3);
    assert_eq!(st.errors, 1, "exactly the poisoned member fails");
    assert_eq!(st.faults, 0, "a NonFinite member is not an executor fault");
}

#[test]
fn admission_control_rejects_at_queue_capacity() {
    // Depth-1 queue, no batching, and enough work per request (scale 10)
    // that back-to-back submissions outrun the drain.
    let g = graph(10);
    let spec = ModelZoo::builtin().resolve("gcn").unwrap();
    let mut engine = Engine::new(EngineConfig {
        queue_depth: 1,
        batch_max: 1,
        ..EngineConfig::default()
    });
    let id = engine.register(&spec, ModelDims::uniform(2, 16), g).unwrap();
    let mut tickets = Vec::new();
    let mut rejected = 0u64;
    for s in 0..64u64 {
        match engine.submit_seeded(id, s) {
            Ok(t) => tickets.push(t),
            Err(ServeError::Rejected { depth, .. }) => {
                assert_eq!(depth, 1);
                rejected += 1;
            }
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    assert!(
        rejected > 0,
        "64 back-to-back submissions into a depth-1 queue never tripped admission control"
    );
    // Every admitted request still completes, in order, successfully.
    let mut last_seq = None;
    for t in tickets {
        let r = t.wait().unwrap();
        if let Some(prev) = last_seq {
            assert!(r.seq > prev, "FIFO order violated: {} after {prev}", r.seq);
        }
        last_seq = Some(r.seq);
    }
    // The engine-side rejection counter agrees with what we observed.
    let st = engine.stats(id).unwrap();
    assert_eq!(st.rejected, rejected);
}

#[test]
fn warm_steady_state_adds_no_scratch_misses_or_spawns() {
    let g = graph(8);
    let spec = ModelZoo::builtin().resolve("gcn").unwrap();
    let mut engine = Engine::new(EngineConfig::default());
    let id = engine.register(&spec, ModelDims::uniform(1, 8), g).unwrap();
    for s in 0..4u64 {
        engine.submit_seeded(id, s).unwrap().wait().unwrap();
    }
    let st1 = engine.stats(id).unwrap();
    for s in 4..12u64 {
        engine.submit_seeded(id, s).unwrap().wait().unwrap();
    }
    let st2 = engine.stats(id).unwrap();
    assert_eq!(st2.requests, 12);
    assert_eq!(
        st1.scratch.misses, st2.scratch.misses,
        "warm engine allocated new scratch arenas in steady state"
    );
    assert!(
        st2.scratch.hits > st1.scratch.hits,
        "later requests should be served entirely from warm pools"
    );
    assert_eq!(
        st1.pool.spawned, st2.pool.spawned,
        "warm engine spawned new worker threads in steady state"
    );
}

/// A spec built to blow up deterministically: exp of huge values makes
/// +inf, and — unlike every zoo model — there is no trailing relu to
/// launder non-finite values back to 0.
const BLOWUP: &str = "
model blowup
dims 1 4 4 4

h = input IN
layer {
  big = unary mul_scalar 1e20 h
  e = unary exp big
  msg = scatter_src e
  agg = gather sum msg
  W = weight DI DO seed 99
  h = dmm agg W
}
output h
";

#[test]
fn non_finite_output_is_a_typed_error_not_a_crash() {
    let g = graph(8);
    let spec = ModelSpec::parse("blowup", BLOWUP).unwrap();
    let mut engine = Engine::new(EngineConfig::default());
    let id = engine.register(&spec, spec.dims(), g.clone()).unwrap();
    match engine.submit_seeded(id, 3).unwrap().wait() {
        Err(ServeError::NonFinite { seq, .. }) => assert_eq!(seq, 0),
        other => panic!("expected NonFinite, got {:?}", other.map(|r| r.seq)),
    }
    // The engine survives: the same entry answers again (still poisoned,
    // still typed), and a healthy entry serves normally alongside it.
    assert!(matches!(
        engine.submit_seeded(id, 4).unwrap().wait(),
        Err(ServeError::NonFinite { .. })
    ));
    let gcn = ModelZoo::builtin().resolve("gcn").unwrap();
    let healthy = engine.register(&gcn, ModelDims::uniform(1, 8), g).unwrap();
    engine.submit_seeded(healthy, 0).unwrap().wait().unwrap();
    let st = engine.stats(id).unwrap();
    assert_eq!(st.errors, 2);
}

#[test]
fn dropped_ticket_does_not_disturb_the_entry() {
    let g = graph(8);
    let spec = ModelZoo::builtin().resolve("gcn").unwrap();
    let mut engine = Engine::new(EngineConfig::default());
    let id = engine.register(&spec, ModelDims::uniform(1, 8), g).unwrap();
    // The caller walks away; the entry's reply lands in a closed channel
    // and must be dropped, not panicked over or blocked on.
    drop(engine.submit_seeded(id, 0).unwrap());
    let r = engine.submit_seeded(id, 1).unwrap().wait().unwrap();
    assert_eq!(r.seq, 1);
    let st = engine.stats(id).unwrap();
    assert_eq!(st.requests, 2, "the abandoned request still executed");
    assert_eq!(st.errors, 0);
}

#[test]
fn submit_after_shutdown_is_typed_not_a_hang() {
    let g = graph(8);
    let spec = ModelZoo::builtin().resolve("gcn").unwrap();
    let mut engine = Engine::new(EngineConfig::default());
    let id = engine.register(&spec, ModelDims::uniform(1, 8), g).unwrap();
    engine.submit_seeded(id, 0).unwrap().wait().unwrap();
    engine.shutdown();
    // Racing the teardown yields a typed error immediately — no hang,
    // no panic — and the stats probe degrades the same way.
    match engine.submit_seeded(id, 1) {
        Err(ServeError::EngineDown { .. }) => {}
        Ok(_) => panic!("submit after shutdown was admitted"),
        Err(e) => panic!("expected EngineDown, got {e}"),
    }
    assert!(matches!(
        engine.stats(id),
        Err(ServeError::EngineDown { .. })
    ));
}

#[test]
fn bench_closed_loop_reports_and_serializes() {
    let g = graph(8);
    let spec = ModelZoo::builtin().resolve("gcn").unwrap();
    let mut engine = Engine::new(EngineConfig::default());
    let id = engine.register(&spec, ModelDims::uniform(1, 8), g).unwrap();
    let report = run_bench(
        &engine,
        &[id],
        &BenchOptions {
            requests: 8,
            ..BenchOptions::default()
        },
    );
    assert_eq!(report.completed, 8);
    assert_eq!(report.rejected, 0);
    assert_eq!(report.errors, 0);
    assert!(report.qps() > 0.0);
    assert!(report.p50() > 0.0 && report.p50() <= report.p99());
    let json = report.to_json();
    for key in ["serve_qps", "serve_p50_ms", "serve_p95_ms", "serve_p99_ms"] {
        assert!(json.contains(&format!("\"{key}\":")), "missing {key}");
    }
}
