//! Observability acceptance tests: the trace layer's end-to-end claims.
//!
//! The trace session is process-global and exclusive (`trace::begin`
//! blocks until the current holder ends), so these tests serialize
//! against each other and against any other test that records — each
//! one owns the span stream it asserts on.

use switchblade::compiler::compile;
use switchblade::exec::{weights, Executor, Matrix, PipelineMode};
use switchblade::graph::{generators, Csr};
use switchblade::ir::models::Model;
use switchblade::isa::Program;
use switchblade::obs::trace::{self, names, Span};
use switchblade::partition::{partition_fggp, PartitionConfig, Partitions};

/// A 2-layer GCN on a skewed graph with budgets small enough to force
/// several destination intervals per group — the same recipe the
/// pipelining differential tests use, so `prepare` spans must appear.
fn workload() -> (Program, Partitions, Matrix, Matrix) {
    let g = Csr::from_edge_list(&generators::rmat(1 << 8, 3_000, 0.57, 0.19, 0.19, 17));
    let ir = Model::Gcn.build(2, 8, 8, 8);
    let prog = compile(&ir);
    let cfg = PartitionConfig {
        shard_bytes: 2 * 1024,
        dst_bytes: 4 * 1024,
        dim_src: prog.dim_src.max(1),
        dim_edge: prog.dim_edge.max(1),
        dim_dst: prog.dim_dst.max(1),
        num_sthreads: 1,
    };
    let parts = partition_fggp(&g, cfg);
    assert!(parts.intervals.len() > 1, "need intervals to pipeline");
    let x = weights::init_features(7, g.num_vertices(), 8);
    let mut deg = Matrix::zeros(g.num_vertices(), 1);
    for v in 0..g.num_vertices() {
        deg.set(v, 0, g.in_degree(v as u32) as f32);
    }
    (prog, parts, x, deg)
}

fn traced_run(prog: &Program, parts: &Partitions, x: &Matrix, deg: &Matrix, workers: usize) -> trace::Trace {
    let sess = trace::begin();
    let mut ex = Executor::new(prog, parts)
        .with_workers(workers)
        .with_pipeline_mode(PipelineMode::Interval);
    let _ = ex.run(x, deg);
    assert!(ex.prepared_intervals() > 0, "pipelining never engaged");
    sess.end()
}

/// Everything identity-like about a span except its timing.
fn keys(spans: &[Span]) -> Vec<(&'static str, &'static str, u32, i32, i32, i32)> {
    spans
        .iter()
        .map(|s| (s.name, s.cat, s.track, s.group, s.interval, s.shard))
        .collect()
}

#[test]
fn single_worker_span_stream_is_deterministic() {
    // With one worker everything runs on the driving thread, so two
    // identical runs must record the identical span sequence (names,
    // lanes and indices; durations of course differ).
    let (prog, parts, x, deg) = workload();
    let a = traced_run(&prog, &parts, &x, &deg, 1);
    let b = traced_run(&prog, &parts, &x, &deg, 1);
    assert!(!a.spans.is_empty());
    assert_eq!(a.dropped, 0);
    assert_eq!(keys(&a.spans), keys(&b.spans));
}

#[test]
fn chrome_export_shape_is_loadable() {
    let (prog, parts, x, deg) = workload();
    let tr = traced_run(&prog, &parts, &x, &deg, 2);
    let json = tr.to_chrome_json();
    assert!(json.starts_with("{\"traceEvents\":["));
    assert!(json.trim_end().ends_with('}'));
    assert!(json.contains("\"displayTimeUnit\":\"ms\""));
    // Metadata names the process and one lane per track.
    assert!(json.contains("\"ph\":\"M\""));
    assert!(json.contains("\"name\":\"switchblade\""));
    assert!(json.contains("\"name\":\"main/prepare\""));
    assert!(json.contains("\"name\":\"worker "), "no worker lane in export");
    // Complete events carry the walk vocabulary.
    assert!(json.contains("\"ph\":\"X\""));
    for name in [names::INTERVAL, names::SCATTER, names::GATHER_DRAIN, names::SHARD] {
        assert!(
            json.contains(&format!("\"name\":\"{name}\"")),
            "missing {name} events"
        );
    }
    // Cheap well-formedness probe without a JSON dependency: the export
    // is brace-balanced and every event line is one object.
    assert_eq!(json.matches('{').count(), json.matches('}').count());
}

#[test]
fn pipelined_prepare_overlaps_the_gather_drain() {
    // The tentpole visual claim: with the interval pipeline on, the
    // next interval's `prepare` runs inside the current interval's
    // `gather_drain` window — nested on the main lane while `shard`
    // spans fill the worker lanes.
    let (prog, parts, x, deg) = workload();
    let tr = traced_run(&prog, &parts, &x, &deg, 2);
    let preps = tr.named(names::PREPARE);
    let drains = tr.named(names::GATHER_DRAIN);
    assert!(!preps.is_empty(), "no prepare spans recorded");
    assert!(!drains.is_empty(), "no gather_drain spans recorded");
    assert!(
        preps.iter().any(|p| drains.iter().any(|d| d.contains(p))),
        "no prepare span nested under a gather_drain span"
    );
    // And the drained shards really ran on worker lanes.
    assert!(tr
        .named(names::SHARD)
        .iter()
        .all(|s| s.track != trace::TRACK_MAIN));
}

#[test]
fn untraced_run_records_nothing() {
    // Hold the exclusive session so no concurrent test can record, then
    // run the executor on a thread with no session flag: every guard on
    // its path must take the disabled branch and leave the global
    // counter untouched.
    let (prog, parts, x, deg) = workload();
    let sess = trace::begin();
    let before = trace::recorded_total();
    let out = std::thread::scope(|s| {
        s.spawn(|| {
            assert!(!trace::active());
            let mut ex = Executor::new(&prog, &parts)
                .with_workers(2)
                .with_pipeline_mode(PipelineMode::Interval);
            ex.run(&x, &deg)
        })
        .join()
        .unwrap()
    });
    assert_eq!(out.rows, x.rows);
    assert_eq!(trace::recorded_total() - before, 0);
    assert!(sess.end().spans.is_empty());
}

#[test]
fn session_opened_after_pool_creation_sees_worker_lanes() {
    // The persistent-pool trace-gating fix: workers spawn once, at the
    // first drain, and must still record spans for sessions opened
    // *afterwards* — the enable flag is sampled per drain on the driving
    // thread and handed to the pool with each batch, not captured at
    // spawn time. An untraced warmup run creates the pool; a session
    // opened only then must still see shard spans on the worker lanes.
    let (prog, parts, x, deg) = workload();
    let mut ex = Executor::new(&prog, &parts)
        .with_workers(2)
        .with_pipeline_mode(PipelineMode::Interval);
    let warm = ex.run(&x, &deg); // pool threads spawn here, untraced
    let sess = trace::begin();
    let traced = ex.run(&x, &deg); // same threads, now-open session
    let tr = sess.end();
    assert!(warm.bits_eq(&traced), "traced rerun diverged bitwise");
    let shards = tr.named(names::SHARD);
    assert!(
        !shards.is_empty(),
        "persistent workers recorded no shard spans for a late-opened session"
    );
    assert!(
        shards.iter().all(|s| s.track != trace::TRACK_MAIN),
        "pooled shard spans must live on worker lanes"
    );
}

#[test]
fn run_profiled_composes_with_an_open_session() {
    // `--profile` under `--trace`: run_profiled borrows the open session
    // (re-entrant begin), folds its profile from a tail slice of the
    // same stream, and leaves every span in the session for export.
    let (prog, parts, x, deg) = workload();
    let sess = trace::begin();
    let mut ex = Executor::new(&prog, &parts)
        .with_workers(2)
        .with_pipeline_mode(PipelineMode::Interval);
    let (_, profile) = ex.run_profiled(&x, &deg);
    assert_eq!(profile.groups.len(), prog.groups.len());
    assert!(profile.total_s() > 0.0);
    let tr = sess.end();
    assert!(
        !tr.named(names::INTERVAL).is_empty(),
        "outer session lost the profiled walk's spans"
    );
}
