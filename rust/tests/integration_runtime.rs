//! Three-way numerics integration: for every model, on the validation
//! graph shape, the JAX→HLO→PJRT path, the Rust IR reference, and the
//! compiled-ISA executor must agree. This is the paper's "simulator
//! validated against DGL built-in models" check, with the AOT'd JAX
//! models in DGL's role.
//!
//! Requires `make artifacts` (skips with a message if absent) AND the
//! `pjrt` cargo feature: the whole file is compiled out by default
//! because the `xla`/`anyhow` crates it needs are unavailable in the
//! offline image (`cargo test --features pjrt` once they resolve).
#![cfg(feature = "pjrt")]

use switchblade::compiler::compile;
use switchblade::exec::{reference, weights, Executor, Matrix};
use switchblade::graph::{Csr, EdgeList};
use switchblade::ir::models::Model;
use switchblade::partition::{partition_fggp, PartitionConfig};
use switchblade::runtime::{artifacts_dir, ArtifactShape, Runtime};

/// The validation graph: deterministic RMAT at the artifact shape.
fn validation_graph(shape: ArtifactShape) -> (Csr, Vec<i32>, Vec<i32>) {
    let el = switchblade::graph::generators::rmat(shape.n, shape.e, 0.57, 0.19, 0.19, 99);
    let g = Csr::from_edge_list(&el);
    // Canonical edge order (the order edge features use everywhere).
    let mut src = vec![0i32; shape.e];
    let mut dst = vec![0i32; shape.e];
    for (s, d, id) in g.edges_canonical() {
        src[id as usize] = s as i32;
        dst[id as usize] = d as i32;
    }
    (g, src, dst)
}

fn degree_col(g: &Csr) -> Vec<f32> {
    (0..g.num_vertices())
        .map(|v| g.in_degree(v as u32) as f32)
        .collect()
}

#[test]
fn pjrt_matches_reference_and_executor() {
    let shape = ArtifactShape::default();
    let dir = artifacts_dir();
    if !dir.join(shape.file_name("gcn")).exists() {
        eprintln!(
            "SKIP: artifacts not built (run `make artifacts`); looked in {}",
            dir.display()
        );
        return;
    }
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let (g, src, dst) = validation_graph(shape);
    let x = weights::init_features(7, shape.n, shape.d);
    let deg = degree_col(&g);
    let deg_m = Matrix::from_vec(shape.n, 1, deg.clone());

    for model in Model::ALL {
        let name = model.name().to_lowercase();
        let exe = rt
            .load_model(&dir, &name, shape)
            .unwrap_or_else(|e| panic!("loading {name}: {e:#}"));
        let got_pjrt = exe.run(&x, &src, &dst, &deg).expect("pjrt run");

        // Rust IR reference.
        let ir = model.build(2, shape.d as u32, shape.d as u32, shape.d as u32);
        let want = reference::evaluate(&ir, &g, &x);
        let diff = got_pjrt.max_abs_diff(&want);
        assert!(
            got_pjrt.allclose(&want, 1e-3, 1e-4),
            "{name}: PJRT vs rust reference max|Δ| = {diff}"
        );

        // Compiled ISA executor over FGGP partitions.
        let prog = compile(&ir);
        let cfg = PartitionConfig {
            shard_bytes: 8 * 1024,
            dst_bytes: 16 * 1024,
            dim_src: prog.dim_src.max(1),
            dim_edge: prog.dim_edge.max(1),
            dim_dst: prog.dim_dst.max(1),
            num_sthreads: 1,
        };
        let parts = partition_fggp(&g, cfg);
        let got_exec = Executor::new(&prog, &parts).run(&x, &deg_m);
        let diff = got_exec.max_abs_diff(&got_pjrt);
        assert!(
            got_exec.allclose(&got_pjrt, 1e-3, 1e-4),
            "{name}: executor vs PJRT max|Δ| = {diff}"
        );
        println!("{name}: three-way agreement OK (max|Δ| = {diff:.2e})");
    }
}

#[test]
fn toy_artifact_round_trips() {
    let dir = artifacts_dir();
    let toy = dir.join("model.hlo.txt");
    if !toy.exists() {
        eprintln!("SKIP: {} missing (run `make artifacts`)", toy.display());
        return;
    }
    let rt = Runtime::cpu().expect("client");
    let exe = rt.load_hlo(&toy).expect("compile toy");
    // toy(x, y) = x @ y + 2 over f32[8,8].
    let x = xla::Literal::vec1(&vec![1f32; 64]).reshape(&[8, 8]).unwrap();
    let y = xla::Literal::vec1(&vec![0f32; 64]).reshape(&[8, 8]).unwrap();
    let out = exe.execute::<xla::Literal>(&[x, y]).unwrap()[0][0]
        .to_literal_sync()
        .unwrap();
    let vals = out.to_tuple1().unwrap().to_vec::<f32>().unwrap();
    assert_eq!(vals, vec![2f32; 64]);
}

#[test]
fn isolated_vertices_agree_across_paths() {
    // Shape-compatible graph with guaranteed isolated destinations:
    // all 256 edges land on the first 8 vertices.
    let shape = ArtifactShape::default();
    let dir = artifacts_dir();
    if !dir.join(shape.file_name("gat")).exists() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let mut el = EdgeList::new(shape.n);
    for k in 0..shape.e {
        let s = (k % shape.n) as u32;
        let d = (k % 8) as u32;
        el.push(s, d);
    }
    let g = Csr::from_edge_list(&el);
    let mut srcs = Vec::new();
    let mut dsts = Vec::new();
    for (s, d, _id) in g.edges_canonical() {
        srcs.push(s as i32);
        dsts.push(d as i32);
    }
    let x = weights::init_features(11, shape.n, shape.d);
    let deg = degree_col(&g);
    let rt = Runtime::cpu().expect("client");
    let exe = rt.load_model(&dir, "gat", shape).expect("load gat");
    let got = exe.run(&x, &srcs, &dsts, &deg).expect("run");
    let ir = Model::Gat.build(2, shape.d as u32, shape.d as u32, shape.d as u32);
    let want = reference::evaluate(&ir, &g, &x);
    assert!(
        got.allclose(&want, 1e-3, 1e-4),
        "GAT isolated-vertex mismatch: {}",
        got.max_abs_diff(&want)
    );
}

#[test]
fn training_step_reduces_loss() {
    // The AOT-lowered backward pass (jax.value_and_grad → HLO text) driven
    // by the Rust SGD loop must reduce a realisable teacher loss.
    let shape = ArtifactShape::default();
    let dir = artifacts_dir();
    let train_artifact = dir.join(format!(
        "gcn_train_n{}_e{}_d{}.hlo.txt",
        shape.n, shape.e, shape.d
    ));
    if !train_artifact.exists() {
        eprintln!("SKIP: {} missing (run `make artifacts`)", train_artifact.display());
        return;
    }
    let rt = Runtime::cpu().expect("client");
    let mut trainer = rt.load_trainer(&dir, "gcn", shape, 50.0).expect("trainer");
    let (g, src, dst) = validation_graph(shape);
    let deg = degree_col(&g);
    let x = weights::init_features(7, shape.n, shape.d);
    let ir = Model::Gcn.build(2, shape.d as u32, shape.d as u32, shape.d as u32);
    let mut target = reference::evaluate(&ir, &g, &x);
    for v in &mut target.data {
        *v *= 2.0;
    }
    let first = trainer.step(&x, &src, &dst, &deg, &target).expect("step");
    let mut last = first;
    for _ in 0..80 {
        last = trainer.step(&x, &src, &dst, &deg, &target).expect("step");
    }
    assert!(
        last < first * 0.5,
        "loss must halve: {first:.3e} -> {last:.3e}"
    );
}
