//! Compiler ↔ partitioner ↔ executor integration: compile every model at
//! several widths, partition real dataset stand-ins with both methods,
//! and check structural + numeric invariants end to end.

use switchblade::compiler::compile;
use switchblade::exec::{reference, weights, Executor, Matrix};
use switchblade::graph::datasets::Dataset;
use switchblade::graph::Csr;
use switchblade::ir::models::Model;
use switchblade::partition::{partition_dsw, partition_fggp};
use switchblade::sim::AcceleratorConfig;

fn degree_col(g: &Csr) -> Matrix {
    let mut d = Matrix::zeros(g.num_vertices(), 1);
    for v in 0..g.num_vertices() {
        d.set(v, 0, g.in_degree(v as u32) as f32);
    }
    d
}

#[test]
fn all_models_all_datasets_numerics() {
    // Small-scale stand-ins of every dataset, both partitioners.
    let accel = AcceleratorConfig::switchblade();
    for d in Dataset::ALL {
        let g = d.load(12);
        for m in Model::ALL {
            let ir = m.build(2, 8, 8, 8);
            let prog = compile(&ir);
            let pc = accel.partition_config(&prog);
            let x = weights::init_features(3, g.num_vertices(), 8);
            let deg = degree_col(&g);
            let want = reference::evaluate(&ir, &g, &x);
            for parts in [partition_fggp(&g, pc), partition_dsw(&g, pc)] {
                parts.validate().unwrap();
                let got = Executor::new(&prog, &parts).run(&x, &deg);
                assert!(
                    got.allclose(&want, 1e-4, 1e-5),
                    "{} on {} ({:?}): {}",
                    m.name(),
                    d.code(),
                    parts.method,
                    got.max_abs_diff(&want)
                );
            }
        }
    }
}

#[test]
fn wide_and_narrow_dims_compile_and_execute() {
    let g = Dataset::Ak.load(6);
    let accel = AcceleratorConfig::switchblade();
    for (di, dh, do_) in [(4, 8, 2), (32, 16, 8), (1, 1, 1)] {
        for m in [Model::Gcn, Model::Gat, Model::Sage] {
            let ir = m.build(2, di, dh, do_);
            let prog = compile(&ir);
            let parts = partition_fggp(&g, accel.partition_config(&prog));
            let x = weights::init_features(5, g.num_vertices(), di as usize);
            let got = Executor::new(&prog, &parts).run(&x, &degree_col(&g));
            let want = reference::evaluate(&ir, &g, &x);
            assert!(
                got.allclose(&want, 1e-4, 1e-5),
                "{} dims ({di},{dh},{do_}): {}",
                m.name(),
                got.max_abs_diff(&want)
            );
        }
    }
}

#[test]
fn deep_models_compile_and_execute() {
    // 4-layer stacks: more groups, more cross-group spills.
    let g = Dataset::Ak.load(8);
    let accel = AcceleratorConfig::switchblade();
    for m in Model::ALL {
        let ir = m.build(4, 8, 8, 8);
        let prog = compile(&ir);
        let parts = partition_fggp(&g, accel.partition_config(&prog));
        let x = weights::init_features(9, g.num_vertices(), 8);
        let got = Executor::new(&prog, &parts).run(&x, &degree_col(&g));
        let want = reference::evaluate(&ir, &g, &x);
        assert!(
            got.allclose(&want, 1e-3, 1e-4),
            "{} x4 layers: {}",
            m.name(),
            got.max_abs_diff(&want)
        );
    }
}
