//! Chaos integration: deterministic fault injection against the real
//! stack — the panic-isolated worker pool, the self-healing serve
//! entries, request deadlines — plus the disarmed differential that
//! pins the injector's zero-cost claim.
//!
//! The injector is process-global, so every test that arms it holds
//! [`chaos_lock`] and disarms on drop; this file is its own test binary,
//! so nothing outside it can race the armed plans.

use std::sync::{Arc, Mutex, MutexGuard, OnceLock, PoisonError};
use std::time::Duration;

use switchblade::compiler::compile;
use switchblade::coordinator::{degree_column, reference_run};
use switchblade::exec::weights::init_features;
use switchblade::exec::{Executor, Matrix, PoolError};
use switchblade::graph::datasets::Dataset;
use switchblade::graph::Csr;
use switchblade::ir::spec::ModelDims;
use switchblade::ir::zoo::ModelZoo;
use switchblade::obs::faultinject;
use switchblade::serve::{Engine, EngineConfig, ServeError};

/// Serializes every test that arms the process-global injector.
fn chaos_lock() -> MutexGuard<'static, ()> {
    static L: OnceLock<Mutex<()>> = OnceLock::new();
    L.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
}

/// Disarm on every exit path, including assertion panics.
struct Disarm;
impl Drop for Disarm {
    fn drop(&mut self) {
        faultinject::disarm();
    }
}

fn graph(scale: u32) -> Arc<Csr> {
    Arc::new(Dataset::Ak.load(scale))
}

fn arm(spec: &str) {
    faultinject::arm(faultinject::parse(spec).unwrap());
}

/// How many times one full executor run passes shard 0's injection
/// site (once per group the walk drives the shard through). Measured,
/// not assumed: the schedule arithmetic of the panic tests — "skip the
/// warm-up run exactly" — needs the real per-run pass count for the
/// same model/graph/config the engine will serve.
fn shard0_passes_per_run(cfg: &EngineConfig, g: &Csr) -> u64 {
    let spec = ModelZoo::builtin().resolve("gcn").unwrap();
    let ir = spec.build(ModelDims::uniform(2, 8)).unwrap();
    arm("slow_shard@shard=0@delay_ms=0@count=1000000");
    let before = faultinject::fired_total();
    let _ = reference_run(
        &ir,
        g,
        &cfg.accel,
        cfg.method,
        cfg.workers,
        cfg.kernel,
        cfg.pipeline,
        0,
    );
    faultinject::disarm();
    faultinject::fired_total() - before
}

/// The acceptance scenario: an injected worker panic fails exactly the
/// in-flight request with a typed cause, the entry restarts its warm
/// executor, and the next request is bit-identical to an uninjected
/// reference run.
#[test]
fn serve_worker_panic_fails_only_in_flight_then_recovers() {
    let _l = chaos_lock();
    let _d = Disarm;
    let g = graph(8);
    let cfg = EngineConfig::default();
    let spec = ModelZoo::builtin().resolve("gcn").unwrap();
    let dims = ModelDims::uniform(2, 8);
    let ir = spec.build(dims).unwrap();
    // Uninjected reference for the post-recovery request, computed
    // while disarmed.
    let want = reference_run(
        &ir,
        &g,
        &cfg.accel,
        cfg.method,
        cfg.workers,
        cfg.kernel,
        cfg.pipeline,
        1,
    );
    let passes = shard0_passes_per_run(&cfg, &g);
    assert!(passes >= 1, "probe run never reached shard 0's site");

    // Skip exactly the warm-up run, so the fault lands on request 0.
    arm(&format!("worker_panic@shard=0@skip={passes}"));
    let mut engine = Engine::new(cfg);
    let id = engine.register(&spec, dims, g.clone()).unwrap();
    match engine.submit_seeded(id, 0).unwrap().wait() {
        Err(ServeError::Faulted { seq, cause, .. }) => {
            assert_eq!(seq, 0, "fault hit the wrong request");
            assert!(
                cause.contains("worker_panic"),
                "cause lost the injected panic message: {cause}"
            );
        }
        Err(other) => panic!("expected Faulted, got {other}"),
        Ok(r) => panic!("injected panic did not surface (seq {})", r.seq),
    }
    // The rebuilt entry serves the next request bit-identically.
    let r = engine.submit_seeded(id, 1).unwrap().wait().unwrap();
    assert!(
        r.out.bits_eq(&want),
        "post-recovery output diverged bitwise from the uninjected reference \
         (max |delta| {})",
        r.out.max_abs_diff(&want)
    );
    let st = engine.stats(id).unwrap();
    assert_eq!(st.faults, 1, "exactly one request faulted");
    assert_eq!(st.restarts, 1, "exactly one executor rebuild");
    assert_eq!(st.errors, 0);
    assert_eq!(st.rung, 0, "one fault must not degrade the entry");
    assert!(!st.quarantined);
    assert_eq!(st.requests, 2);
}

/// Executor-direct: `try_run` surfaces the injected panic as a typed
/// `WorkerPanicked` naming the canonical shard, the pool heals (visible
/// in `respawned`), and the healed executor is bit-identical.
#[test]
fn executor_worker_panic_is_typed_and_the_pool_heals() {
    let _l = chaos_lock();
    let _d = Disarm;
    let g = Dataset::Ak.load(8);
    let cfg = EngineConfig::default();
    let spec = ModelZoo::builtin().resolve("gcn").unwrap();
    let ir = spec.build(ModelDims::uniform(2, 8)).unwrap();
    let prog = compile(&ir);
    let parts = cfg.method.run(&g, cfg.accel.partition_config(&prog));
    let x = init_features(7, g.num_vertices(), ir.input_dim() as usize);
    let deg = degree_column(&g);
    let want = Executor::new(&prog, &parts).with_workers(4).run(&x, &deg);

    let mut ex = Executor::new(&prog, &parts).with_workers(4);
    arm("worker_panic@shard=0");
    match ex.try_run(&x, &deg) {
        Err(PoolError::WorkerPanicked { shard, msg, .. }) => {
            assert_eq!(shard, 0, "fault reported at the wrong shard");
            assert!(msg.contains("worker_panic"), "panic message lost: {msg}");
        }
        Err(other) => panic!("expected WorkerPanicked, got {other}"),
        Ok(_) => panic!("injected panic did not surface"),
    }
    assert!(
        ex.pool_stats().respawned >= 1,
        "pool never recorded the heal (respawned = {})",
        ex.pool_stats().respawned
    );
    let got = ex.try_run(&x, &deg).expect("healed executor must serve again");
    assert!(
        got.bits_eq(&want),
        "healed executor diverged bitwise (max |delta| {})",
        got.max_abs_diff(&want)
    );
}

/// Same contract with a single worker: the inline (thread-free) path
/// catches the panic, rebuilds its scratch, and stays bit-identical.
#[test]
fn inline_executor_worker_panic_heals_without_threads() {
    let _l = chaos_lock();
    let _d = Disarm;
    let g = Dataset::Ak.load(8);
    let cfg = EngineConfig::default();
    let spec = ModelZoo::builtin().resolve("gcn").unwrap();
    let ir = spec.build(ModelDims::uniform(2, 8)).unwrap();
    let prog = compile(&ir);
    let parts = cfg.method.run(&g, cfg.accel.partition_config(&prog));
    let x = init_features(7, g.num_vertices(), ir.input_dim() as usize);
    let deg = degree_column(&g);
    let want = Executor::new(&prog, &parts).with_workers(1).run(&x, &deg);

    let mut ex = Executor::new(&prog, &parts).with_workers(1);
    arm("worker_panic@shard=0");
    match ex.try_run(&x, &deg) {
        Err(PoolError::WorkerPanicked { worker, shard, .. }) => {
            assert_eq!(worker, 0);
            assert_eq!(shard, 0);
        }
        Err(other) => panic!("expected WorkerPanicked, got {other}"),
        Ok(_) => panic!("injected panic did not surface"),
    }
    assert!(ex.pool_stats().respawned >= 1);
    let got = ex.try_run(&x, &deg).expect("healed inline executor serves again");
    assert!(got.bits_eq(&want), "inline recovery diverged bitwise");
}

/// A straggler worker (injected sleep) must change timing only — the
/// deterministic merge keeps the output bit-identical.
#[test]
fn slow_shard_changes_timing_not_bits() {
    let _l = chaos_lock();
    let _d = Disarm;
    let g = Dataset::Ak.load(8);
    let cfg = EngineConfig::default();
    let spec = ModelZoo::builtin().resolve("gcn").unwrap();
    let ir = spec.build(ModelDims::uniform(2, 8)).unwrap();
    let prog = compile(&ir);
    let parts = cfg.method.run(&g, cfg.accel.partition_config(&prog));
    let x = init_features(7, g.num_vertices(), ir.input_dim() as usize);
    let deg = degree_column(&g);
    let want = Executor::new(&prog, &parts).with_workers(4).run(&x, &deg);

    let before = faultinject::fired_total();
    arm("slow_shard@shard=0@delay_ms=20@count=8");
    let got = Executor::new(&prog, &parts).with_workers(4).run(&x, &deg);
    assert!(
        faultinject::fired_total() > before,
        "slow_shard never fired — the site is not wired"
    );
    assert!(
        got.bits_eq(&want),
        "a straggler worker changed the output bits (max |delta| {})",
        got.max_abs_diff(&want)
    );
}

/// An injected NaN rides the existing non-finite guard: a typed
/// `NonFinite` error for that request alone — no fault, no restart.
#[test]
fn nonfinite_injection_fails_one_request_without_a_restart() {
    let _l = chaos_lock();
    let _d = Disarm;
    let g = graph(8);
    let cfg = EngineConfig::default();
    let spec = ModelZoo::builtin().resolve("gcn").unwrap();
    let dims = ModelDims::uniform(1, 8);
    let ir = spec.build(dims).unwrap();
    let want = reference_run(
        &ir,
        &g,
        &cfg.accel,
        cfg.method,
        cfg.workers,
        cfg.kernel,
        cfg.pipeline,
        1,
    );
    let mut engine = Engine::new(cfg);
    let id = engine.register(&spec, dims, g.clone()).unwrap();
    arm("nonfinite_output");
    match engine.submit_seeded(id, 0).unwrap().wait() {
        Err(ServeError::NonFinite { seq, .. }) => assert_eq!(seq, 0),
        other => panic!("expected NonFinite, got {:?}", other.map(|r| r.seq)),
    }
    let r = engine.submit_seeded(id, 1).unwrap().wait().unwrap();
    assert!(r.out.bits_eq(&want), "request after a poisoned one diverged");
    let st = engine.stats(id).unwrap();
    assert_eq!(st.errors, 1);
    assert_eq!(st.faults, 0, "a poisoned output is not an executor fault");
    assert_eq!(st.restarts, 0, "a poisoned output must not trigger a rebuild");
}

/// A stalled entry loop makes the bounded queue observable: admitted
/// work completes, the overflow is rejected with the typed error.
#[test]
fn queue_stall_trips_admission_control() {
    let _l = chaos_lock();
    let _d = Disarm;
    let g = graph(8);
    let spec = ModelZoo::builtin().resolve("gcn").unwrap();
    let mut engine = Engine::new(EngineConfig {
        queue_depth: 1,
        batch_max: 1,
        ..EngineConfig::default()
    });
    let id = engine.register(&spec, ModelDims::uniform(1, 8), g).unwrap();
    arm("queue_stall@delay_ms=50@count=64");
    let mut tickets = Vec::new();
    let mut rejected = 0u64;
    for s in 0..16u64 {
        match engine.submit_seeded(id, s) {
            Ok(t) => tickets.push(t),
            Err(ServeError::Rejected { depth, .. }) => {
                assert_eq!(depth, 1);
                rejected += 1;
            }
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    assert!(
        rejected > 0,
        "a stalled depth-1 queue never rejected a submission"
    );
    // The stats probe must not block behind the saturation it is
    // observing: a typed answer either way, immediately.
    match engine.stats(id) {
        Ok(_) | Err(ServeError::StatsUnavailable { .. }) => {}
        Err(e) => panic!("stats under saturation: unexpected {e}"),
    }
    for t in tickets {
        t.wait().expect("admitted requests complete despite the stall");
    }
}

/// Deadlines bound both halves of the round trip: a request expiring in
/// the queue is answered `DeadlineExceeded` at dequeue without running,
/// and `wait_timeout` bounds the caller even with no deadline set.
#[test]
fn deadlines_expire_under_a_stalled_entry() {
    let _l = chaos_lock();
    let _d = Disarm;
    let g = graph(8);
    let spec = ModelZoo::builtin().resolve("gcn").unwrap();
    let mut engine = Engine::new(EngineConfig::default());
    let id = engine.register(&spec, ModelDims::uniform(1, 8), g).unwrap();
    arm("queue_stall@delay_ms=60@count=4");

    // Entry-side: expired while queued → answered without execution.
    let t = engine
        .submit_seeded_deadline(id, 0, Duration::from_millis(5))
        .unwrap();
    match t.wait() {
        Err(ServeError::DeadlineExceeded { seq, .. }) => assert_eq!(seq, 0),
        other => panic!("expected DeadlineExceeded, got {:?}", other.map(|r| r.seq)),
    }

    // Caller-side: wait_timeout gives up during the stall even though
    // the request itself carries no deadline.
    let t = engine.submit_seeded(id, 1).unwrap();
    match t.wait_timeout(Duration::from_millis(5)) {
        Err(ServeError::DeadlineExceeded { .. }) => {}
        other => panic!("expected DeadlineExceeded, got {:?}", other.map(|r| r.seq)),
    }

    faultinject::disarm();
    // The entry recovers its cadence once the stalls exhaust.
    engine.submit_seeded(id, 2).unwrap().wait().unwrap();
    let st = engine.stats(id).unwrap();
    assert_eq!(st.timeouts, 1, "only the queued expiry counts entry-side");
    assert_eq!(st.faults, 0);
    assert_eq!(st.restarts, 0);
}

/// The disarmed differential the module docs promise: with no plan
/// armed, outputs are bit-identical to the reference, nothing fires,
/// nothing restarts, and the warm steady state still adds no scratch
/// misses — injection hooks cost one atomic load and change nothing.
#[test]
fn disarmed_injector_changes_nothing() {
    let _l = chaos_lock();
    assert!(!faultinject::armed());
    let fired0 = faultinject::fired_total();
    let g = graph(8);
    let cfg = EngineConfig::default();
    let spec = ModelZoo::builtin().resolve("gcn").unwrap();
    let dims = ModelDims::uniform(1, 8);
    let ir = spec.build(dims).unwrap();
    let mut engine = Engine::new(cfg);
    let id = engine.register(&spec, dims, g.clone()).unwrap();
    let outs: Vec<Matrix> = (0..4u64)
        .map(|s| engine.submit_seeded(id, s).unwrap().wait().unwrap().out)
        .collect();
    for (s, out) in outs.iter().enumerate() {
        let want = reference_run(
            &ir,
            &g,
            &cfg.accel,
            cfg.method,
            cfg.workers,
            cfg.kernel,
            cfg.pipeline,
            s as u64,
        );
        assert!(
            out.bits_eq(&want),
            "seed {s}: output diverged with the injector merely present"
        );
    }
    let st1 = engine.stats(id).unwrap();
    for s in 4..12u64 {
        engine.submit_seeded(id, s).unwrap().wait().unwrap();
    }
    let st2 = engine.stats(id).unwrap();
    assert_eq!(
        st1.scratch.misses, st2.scratch.misses,
        "disarmed hooks cost scratch misses in steady state"
    );
    assert_eq!(st2.faults, 0);
    assert_eq!(st2.restarts, 0);
    assert_eq!(st2.timeouts, 0);
    assert_eq!(st2.rung, 0);
    assert_eq!(st2.pool.respawned, 0);
    assert_eq!(
        faultinject::fired_total(),
        fired0,
        "something fired with no plan armed"
    );
}
