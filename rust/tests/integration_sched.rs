//! Scheduler order-equivalence: the functional executor and the cycle
//! simulator both drive `sched::PartitionWalk`, so their `(group,
//! interval, shard, phase)` traces must be identical — to each other and
//! to the canonical trace. This is the property that kills silent drift
//! between the two backends' execution orders.

use switchblade::compiler::compile;
use switchblade::exec::{weights, Executor, Matrix, PipelineMode};
use switchblade::graph::{generators, Csr};
use switchblade::ir::models::Model;
use switchblade::partition::{Method, PartitionConfig};
use switchblade::sched::{canonical_trace, Phase, WalkStep};
use switchblade::sim::{simulate_traced, AcceleratorConfig};

fn degree_col(g: &Csr) -> Matrix {
    let mut d = Matrix::zeros(g.num_vertices(), 1);
    for v in 0..g.num_vertices() {
        d.set(v, 0, g.in_degree(v as u32) as f32);
    }
    d
}

/// Structural checks on a canonical trace: per (group, interval) the
/// phases run Scatter → Gathers (ascending shard index) → Apply, with
/// groups outermost and intervals ascending.
fn assert_well_formed(trace: &[WalkStep]) {
    let mut prev: Option<&WalkStep> = None;
    for s in trace {
        if let Some(p) = prev {
            assert!(
                (s.group, s.interval) >= (p.group, p.interval),
                "walk went backwards: {p:?} -> {s:?}"
            );
            if (s.group, s.interval) == (p.group, p.interval) {
                let rank = |st: &WalkStep| match st.phase {
                    Phase::Scatter => 0,
                    Phase::Gather => 1,
                    Phase::Apply => 2,
                };
                assert!(rank(p) <= rank(s), "phase order violated: {p:?} -> {s:?}");
                if p.phase == Phase::Gather && s.phase == Phase::Gather {
                    assert!(p.shard < s.shard, "shard order violated: {p:?} -> {s:?}");
                }
            }
        }
        prev = Some(s);
    }
}

#[test]
fn executor_and_simulator_walk_identically() {
    let g = Csr::from_edge_list(&generators::rmat(1 << 8, 2_000, 0.57, 0.19, 0.19, 42));
    // Small buffers so every interval has several shards and there are
    // several intervals — a trivial 1×1 walk would prove nothing.
    let cfg = AcceleratorConfig::switchblade()
        .with_src_edge_buffer(48 * 1024)
        .with_dst_buffer(16 * 1024);
    for m in Model::ALL {
        let ir = m.build(2, 8, 8, 8);
        let prog = compile(&ir);
        let pc = cfg.partition_config(&prog);
        for method in Method::ALL {
            let parts = method.run(&g, pc);
            let want = canonical_trace(&prog, &parts);
            assert_well_formed(&want);
            assert!(
                want.iter().any(|s| s.phase == Phase::Gather),
                "{} / {}: degenerate walk without shards",
                m.name(),
                method.name()
            );

            let x = weights::init_features(3, g.num_vertices(), 8);
            let deg = degree_col(&g);
            let (_, exec_trace) = Executor::new(&prog, &parts).run_traced(&x, &deg);
            let (_, sim_trace) = simulate_traced(&prog, &parts, &cfg);
            assert_eq!(
                exec_trace,
                want,
                "{} / {}: executor left the canonical walk",
                m.name(),
                method.name()
            );
            assert_eq!(
                sim_trace,
                want,
                "{} / {}: simulator left the canonical walk",
                m.name(),
                method.name()
            );
        }
    }
}

#[test]
fn pipelined_executor_keeps_canonical_merge_order() {
    // Interval pipelining (PipelineMode::Interval) prepares interval i+1's
    // DstBuffer state under interval i's gather drain — but the observable
    // walk must be untouched: the traced (group, interval, shard, phase)
    // sequence of a pipelined run is exactly the canonical trace (so the
    // deterministic gather-merge order cannot shift), and the output is
    // bit-identical to the sequential PipelineMode::Off reference. The
    // simulator's SLMT timing (which always models this overlap) stays the
    // oracle for what the executor now actually does.
    let g = Csr::from_edge_list(&generators::rmat(1 << 8, 3_000, 0.57, 0.19, 0.19, 51));
    for m in Model::ALL {
        let ir = m.build(2, 8, 8, 8);
        let prog = compile(&ir);
        // Small budgets force several intervals (no intervals, no
        // pipeline) with several shards each.
        let cfg = PartitionConfig {
            shard_bytes: 2 * 1024,
            dst_bytes: 4 * 1024,
            dim_src: prog.dim_src.max(1),
            dim_edge: prog.dim_edge.max(1),
            dim_dst: prog.dim_dst.max(1),
            num_sthreads: 4,
        };
        for method in Method::ALL {
            let parts = method.run(&g, cfg);
            assert!(parts.intervals.len() > 1, "need intervals to pipeline");
            let want = canonical_trace(&prog, &parts);
            let x = weights::init_features(5, g.num_vertices(), 8);
            let deg = degree_col(&g);
            let mut ex = Executor::new(&prog, &parts)
                .with_pipeline_mode(PipelineMode::Interval)
                .with_workers(4);
            let (out_pipe, trace) = ex.run_traced(&x, &deg);
            assert!(
                ex.prepared_intervals() > 0,
                "{} / {}: pipelining never engaged",
                m.name(),
                method.name()
            );
            assert_eq!(
                trace,
                want,
                "{} / {}: pipelined walk left the canonical order",
                m.name(),
                method.name()
            );
            let out_seq = Executor::new(&prog, &parts)
                .with_pipeline_mode(PipelineMode::Off)
                .with_workers(1)
                .run(&x, &deg);
            assert!(
                out_pipe.bits_eq(&out_seq),
                "{} / {}: pipelined output diverged bitwise",
                m.name(),
                method.name()
            );
        }
    }
}

#[test]
fn trace_covers_every_shard_once_per_group() {
    let g = Csr::from_edge_list(&generators::rmat(1 << 7, 900, 0.57, 0.19, 0.19, 7));
    let cfg = AcceleratorConfig::switchblade()
        .with_src_edge_buffer(32 * 1024)
        .with_dst_buffer(8 * 1024);
    let prog = compile(&Model::Gcn.build(2, 8, 8, 8));
    let parts = Method::Fggp.run(&g, cfg.partition_config(&prog));
    let trace = canonical_trace(&prog, &parts);
    let groups = prog.groups.len() as u32;
    for gi in 0..groups {
        let mut seen: Vec<u32> = trace
            .iter()
            .filter(|s| s.group == gi && s.phase == Phase::Gather)
            .map(|s| s.shard.unwrap())
            .collect();
        let expect: Vec<u32> = (0..parts.shards.len() as u32).collect();
        seen.sort_unstable();
        assert_eq!(seen, expect, "group {gi} gather coverage");
    }
}
