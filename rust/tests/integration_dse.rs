//! End-to-end design-space exploration: a tiny budgeted `tune` sweep at
//! small scale, exercising the full search-space → parallel-evaluate →
//! Pareto → report pipeline plus the CSV/JSON emission the CLI uses.

use std::sync::Arc;

use switchblade::dse::{
    tune, Caches, DesignPoint, MemoryKind, Objective, SearchSpace, TuneOptions,
};
use switchblade::graph::datasets::Dataset;
use switchblade::ir::spec::ModelSpec;
use switchblade::ir::zoo::ModelZoo;
use switchblade::partition::Method;

fn gcn() -> Arc<ModelSpec> {
    ModelZoo::builtin().get("gcn").expect("builtin gcn")
}

fn tiny_space() -> SearchSpace {
    SearchSpace {
        sthreads: vec![1, 3, 4],
        dst_buffer_bytes: vec![8 * 1024 * 1024, 13 * 1024 * 1024],
        src_edge_buffer_bytes: vec![1024 * 1024],
        vu: vec![(16, 32)],
        mu: vec![(32, 128), (16, 128)],
        memories: vec![MemoryKind::Hbm1, MemoryKind::Hbm2],
        methods: vec![Method::Fggp, Method::Dsw],
    }
}

/// The `switchblade tune GCN AK --scale 9` acceptance scenario: default
/// search space, default budget.
#[test]
fn tune_gcn_ak_default_space_end_to_end() {
    let caches = Caches::new(9);
    let opts = TuneOptions::default();
    let r = tune(&gcn(), Dataset::Ak, &caches, &opts);

    // Budget respected (+1 possible for the appended Tbl III baseline).
    assert!(
        r.evaluated.len() >= opts.budget && r.evaluated.len() <= opts.budget + 1,
        "{}",
        r.evaluated.len()
    );
    assert_eq!(r.baseline.point, DesignPoint::paper_default());
    for e in &r.evaluated {
        assert!(e.cycles > 0.0 && e.latency_s > 0.0);
        assert!(e.energy_j > 0.0 && e.sram_bytes > 0);
    }

    // A non-trivial frontier spanning several sThread counts (the SEB
    // tiers alone guarantee distinct SRAM champions).
    assert!(r.frontier.len() >= 3, "frontier: {:?}", r.frontier);
    let mut threads: Vec<u32> = r
        .frontier_points()
        .iter()
        .map(|e| e.point.num_sthreads)
        .collect();
    threads.sort_unstable();
    threads.dedup();
    assert!(threads.len() >= 2, "frontier sThreads: {threads:?}");

    // The tuner can never report a best-latency point slower than the
    // paper default it always evaluates.
    assert!(r.best(Objective::Latency).latency_s <= r.baseline.latency_s);
    assert!(r.best(Objective::Energy).energy_j <= r.baseline.energy_j);

    // Points differing only in MU geometry / memory share partitionings.
    assert!(r.caches.partitions.hits > 0, "{}", r.caches.summary());

    // Report artifacts render and write.
    let rendered = r.frontier_table().render();
    assert!(rendered.contains("Pareto frontier"));
    let dir = std::env::temp_dir().join("switchblade_dse_test");
    let csv = dir.join("sweep.csv");
    let json = dir.join("sweep.json");
    r.sweep_table().write_csv(&csv).unwrap();
    r.sweep_table().write_json(&json).unwrap();
    let csv_s = std::fs::read_to_string(&csv).unwrap();
    assert_eq!(csv_s.lines().count(), r.evaluated.len() + 1, "header + one line per point");
    let json_s = std::fs::read_to_string(&json).unwrap();
    assert!(json_s.contains("\"latency ms\""));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn warm_caches_make_repeat_sweeps_free() {
    let caches = Caches::new(10);
    let opts = TuneOptions {
        space: tiny_space(),
        budget: 8,
        objective: Objective::Edp,
    };
    let first = tune(&gcn(), Dataset::Ak, &caches, &opts);
    let after_first = first.caches;
    let second = tune(&gcn(), Dataset::Ak, &caches, &opts);
    let after_second = second.caches;

    // The repeat sweep must not rebuild anything: misses stay flat while
    // lookups grow.
    assert_eq!(after_first.partitions.misses, after_second.partitions.misses);
    assert_eq!(after_first.graphs.misses, after_second.graphs.misses);
    assert_eq!(after_first.programs.misses, after_second.programs.misses);
    assert!(after_second.partitions.hits > after_first.partitions.hits);

    // Determinism: identical sweep → identical results.
    assert_eq!(first.evaluated.len(), second.evaluated.len());
    for (a, b) in first.evaluated.iter().zip(&second.evaluated) {
        assert_eq!(a.point, b.point);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.energy_j, b.energy_j);
    }
    assert_eq!(first.frontier, second.frontier);
}
