//! Full-harness integration: the figure pipelines run end to end at a tiny
//! scale and reproduce the paper's qualitative claims.

use switchblade::coordinator::{Caches, Harness};
use switchblade::graph::datasets::Dataset;
use switchblade::ir::zoo::ModelZoo;
use switchblade::sim::AcceleratorConfig;

fn harness() -> (Harness, Caches) {
    let h = Harness {
        scale: 9,
        ..Default::default()
    };
    let cache = Caches::new(h.scale);
    (h, cache)
}

#[test]
fn sweep_produces_full_grid() {
    let (h, cache) = harness();
    let rows = h.eval_all(&cache);
    let paper = ModelZoo::builtin().paper_models();
    assert_eq!(rows.len(), paper.len() * Dataset::ALL.len());
    for r in &rows {
        assert!(r.sim.cycles > 0.0);
        assert!(r.gpu.seconds > 0.0);
        assert!(r.energy.total_j() > 0.0);
        assert_eq!(r.hygcn.is_some(), r.model.name() == "gcn");
    }
}

#[test]
fn headline_claims_hold_qualitatively() {
    let (h, cache) = harness();
    let rows = h.eval_all(&cache);
    // Fig 7: SWITCHBLADE beats the GPU on average.
    let speedups: Vec<f64> = rows.iter().map(|r| r.speedup_vs_gpu()).collect();
    let geo = switchblade::util::geomean(&speedups);
    assert!(geo > 1.2, "avg speedup {geo:.2} should exceed 1.2x");
    // Fig 8: energy savings are an order of magnitude.
    let savings: Vec<f64> = rows.iter().map(|r| r.energy_saving_vs_gpu()).collect();
    assert!(switchblade::util::geomean(&savings) > 5.0);
    // Fig 9: PLOF moves less data than the op-by-op paradigm everywhere.
    for r in &rows {
        assert!(
            (r.sim.traffic.total() as f64) < r.gpu.dram_bytes as f64,
            "{} on {}: accel traffic must undercut GPU",
            r.model.display(),
            r.dataset.code()
        );
    }
}

#[test]
fn fig12_occupancy_gap() {
    let (h, cache) = harness();
    let t = h.fig12(&cache);
    // FGGP is never worse, is near-full everywhere, and on the skewed
    // graphs (HW, SL) opens a clear gap over the window-sliding baseline.
    for row in &t.rows {
        let fggp: f64 = row[1].parse().unwrap();
        let dsw: f64 = row[2].parse().unwrap();
        assert!(fggp + 1e-9 >= dsw, "{}: FGGP {fggp} < DSW {dsw}", row[0]);
        assert!(fggp > 0.8, "{}: FGGP occupancy {fggp}", row[0]);
        if row[0] == "HW" || row[0] == "SL" {
            assert!(fggp > dsw + 0.1, "{}: FGGP {fggp} vs DSW {dsw}", row[0]);
        }
    }
}

#[test]
fn fig11_u_curve_bottom_not_at_extremes() {
    // At least on the skewed datasets the best thread count should be an
    // interior point (2-4), matching the paper's U-curve.
    let h = Harness {
        scale: 8,
        ..Default::default()
    };
    let cache = Caches::new(h.scale);
    let g = cache.graph(Dataset::Sl);
    let counts = [1u32, 2, 3, 4, 6];
    let gat = ModelZoo::builtin().get("gat").expect("builtin gat");
    let cycles: Vec<f64> = counts
        .iter()
        .map(|&c| {
            h.eval_one(&gat, &g, &h.accel.with_sthreads(c)).2.cycles
        })
        .collect();
    let best = cycles
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.total_cmp(b.1))
        .unwrap()
        .0;
    assert!(
        (1..=3).contains(&best),
        "best sThread index {best} (counts {counts:?}, cycles {cycles:?})"
    );
}

#[test]
fn serving_config_presets_consistent() {
    let accel = AcceleratorConfig::switchblade();
    assert_eq!(accel.num_sthreads, 3); // matched to VU/MU/bandwidth (§VI)
    assert_eq!(accel.shard_bytes(), accel.src_edge_buffer / 3);
}
