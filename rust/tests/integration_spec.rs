//! The open model zoo, end to end: `.gnn` spec files — including models
//! that exist in *no* Rust builder — run compile → partition → simulate →
//! exec and agree with the IR reference oracle; built-in specs reproduce
//! the legacy builders; the program cache keys on the spec fingerprint.

use std::sync::Arc;

use switchblade::compiler::compile;
use switchblade::coordinator::validate_numerics;
use switchblade::dse::{evaluate_one, Caches, DesignPoint, Workload};
use switchblade::graph::datasets::Dataset;
use switchblade::ir::models::Model;
use switchblade::ir::spec::{ModelDims, ModelSpec};
use switchblade::ir::zoo::ModelZoo;
use switchblade::partition::Method;
use switchblade::sim::{simulate, AcceleratorConfig};

const GIN: &str = include_str!("../../examples/models/gin.gnn");
const GCN3: &str = include_str!("../../examples/models/gcn3.gnn");

/// The acceptance scenario: a GIN-style sum-MLP defined purely in a spec
/// file (no Rust builder exists for it) runs the whole stack, and the
/// compiled-ISA executor matches the IR reference to < 1e-4.
#[test]
fn out_of_zoo_gin_spec_end_to_end() {
    let spec = ModelSpec::parse("gin", GIN).unwrap();
    assert_eq!(spec.name(), "gin");
    assert_eq!(spec.dims(), ModelDims::new(2, 32, 32, 32));

    let caches = Caches::new(10);
    let g = caches.graph(Dataset::Ak);
    let accel = AcceleratorConfig::switchblade();

    // compile → partition → simulate.
    let prog = compile(&spec.graph());
    assert!(prog.num_instrs() > 0);
    let parts = Method::Fggp.run(&g, accel.partition_config(&prog));
    parts.validate().unwrap();
    let sim = simulate(&prog, &parts, &accel);
    assert!(sim.cycles > 0.0 && sim.shards_processed > 0);

    // exec vs reference, at a small shape so the dense oracle stays fast.
    let small = spec.build(ModelDims::uniform(2, 16)).unwrap();
    let diff = validate_numerics(&small, &g, &accel);
    assert!(diff < 1e-4, "GIN executor vs reference: {diff}");

    // And the DSE evaluator takes the same spec with no special-casing.
    let w = Workload {
        model: Arc::new(spec),
        dataset: Dataset::Ak,
    };
    let e = evaluate_one(&w, DesignPoint::paper_default(), &caches);
    assert!(e.cycles > 0.0 && e.energy_j > 0.0);
}

#[test]
fn gcn3_spec_pins_dims_and_ranges() {
    let spec = ModelSpec::parse("gcn3", GCN3).unwrap();
    assert_eq!(spec.dims(), ModelDims::new(3, 64, 64, 32));
    let g = spec.graph();
    // Three gather rounds (one per conv layer), 32-wide logits head.
    assert_eq!(g.num_groups(), 3);
    assert_eq!(g.nodes[g.output.unwrap()].cols, 32);
    // The explicit 2..LAYERS range drops the final ReLU.
    assert!(g.nodes.iter().any(|n| n.name == "l1.relu"));
    assert!(!g.nodes.iter().any(|n| n.name == "l2.relu"));
    assert!(g.nodes.iter().any(|n| n.name == "l2.z_norm"));
}

/// Built-in zoo specs are node-for-node the legacy builders (the zoo unit
/// tests cover more shapes; this pins the paper shape from the outside).
#[test]
fn builtin_specs_reproduce_legacy_builders() {
    for m in Model::ALL {
        assert_eq!(m.spec().graph(), m.build_paper(), "{}", m.name());
    }
    // sage_mean is a first-class zoo entry too (Reduce::Mean end to end).
    let sm = ModelZoo::builtin().get("sage_mean").unwrap();
    let caches = Caches::new(10);
    let g = caches.graph(Dataset::Ak);
    let diff = validate_numerics(
        &sm.build(ModelDims::uniform(2, 16)).unwrap(),
        &g,
        &AcceleratorConfig::switchblade(),
    );
    assert!(diff < 1e-4, "sage_mean: {diff}");
}

/// Distinct layers/dims of one model no longer collide in the program
/// cache (the old `Memo<Model, Program>` key ignored them).
#[test]
fn program_cache_keys_on_fingerprint() {
    let caches = Caches::new(10);
    let gcn = ModelZoo::builtin().get("gcn").unwrap();
    let deep = gcn.with_dims(ModelDims::new(3, 64, 64, 64)).unwrap();
    let a = caches.program(&gcn);
    let b = caches.program(&deep);
    assert!(!Arc::ptr_eq(&a, &b), "distinct dims must compile separately");
    assert!(b.num_instrs() > a.num_instrs(), "3 layers emit more code");
    let again = caches.program(&gcn);
    assert!(Arc::ptr_eq(&a, &again));
    assert_eq!(caches.snapshot().programs.hits, 1);
    assert_eq!(caches.snapshot().programs.misses, 2);
}
