"""Pallas kernels vs pure-jnp oracle — the CORE kernel correctness signal.

hypothesis sweeps shapes/dtypes/edge distributions; fixed cases pin the
conventions (empty segments, hub destinations, padding).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.kernels.matmul import matmul as pl_matmul
from compile.kernels.seg_reduce import seg_reduce


def coo(rng, n, e):
    return (
        rng.integers(0, n, size=e).astype(np.int32),
        rng.integers(0, n, size=e).astype(np.int32),
    )


# ---- seg_reduce -----------------------------------------------------------


@pytest.mark.parametrize("reduce", ["sum", "max", "mean"])
def test_seg_reduce_matches_ref_fixed(reduce):
    rng = np.random.default_rng(0)
    n, e, d = 37, 160, 24
    _, dst = coo(rng, n, e)
    vals = rng.standard_normal((e, d)).astype(np.float32)
    got = seg_reduce(vals, dst, n, reduce=reduce)
    want = {"sum": ref.seg_sum, "max": ref.seg_max, "mean": ref.seg_mean}[reduce](
        vals, dst, n
    )
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 80),
    e=st.integers(1, 300),
    d=st.sampled_from([1, 3, 8, 16, 127, 128, 130]),
    reduce=st.sampled_from(["sum", "max", "mean"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_seg_reduce_matches_ref_sweep(n, e, d, reduce, seed):
    rng = np.random.default_rng(seed)
    _, dst = coo(rng, n, e)
    vals = (rng.standard_normal((e, d)) * 4).astype(np.float32)
    got = np.asarray(seg_reduce(vals, dst, n, reduce=reduce))
    want = np.asarray(
        {"sum": ref.seg_sum, "max": ref.seg_max, "mean": ref.seg_mean}[reduce](
            vals, dst, n
        )
    )
    assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_seg_reduce_empty_segments_are_zero():
    # Vertices 5.. receive no edges; the shared convention says 0 even for max.
    n, e, d = 10, 6, 4
    dst = np.zeros(e, np.int32)
    vals = -np.abs(np.random.default_rng(1).standard_normal((e, d))).astype(np.float32)
    for reduce in ["sum", "max", "mean"]:
        out = np.asarray(seg_reduce(vals, dst, n, reduce=reduce))
        assert np.all(out[1:] == 0.0), f"{reduce}: empty rows must be exactly 0"


def test_seg_reduce_hub_destination():
    # All edges land on one vertex (power-law hub).
    n, e, d = 8, 500, 16
    dst = np.full(e, 3, np.int32)
    vals = np.random.default_rng(2).standard_normal((e, d)).astype(np.float32)
    got = np.asarray(seg_reduce(vals, dst, n, reduce="sum"))
    assert_allclose(got[3], vals.sum(axis=0), rtol=1e-4, atol=1e-4)
    assert np.all(got[[0, 1, 2, 4, 5, 6, 7]] == 0)


# ---- matmul ---------------------------------------------------------------


def test_matmul_matches_ref_fixed():
    rng = np.random.default_rng(3)
    a = rng.standard_normal((200, 96)).astype(np.float32)
    w = rng.standard_normal((96, 144)).astype(np.float32)
    assert_allclose(
        np.asarray(pl_matmul(a, w)), np.asarray(ref.matmul(a, w)), rtol=1e-4, atol=1e-4
    )


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 200),
    k=st.sampled_from([1, 7, 16, 128, 130]),
    n=st.sampled_from([1, 5, 64, 128, 129]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref_sweep(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, k)).astype(np.float32)
    w = rng.standard_normal((k, n)).astype(np.float32)
    assert_allclose(
        np.asarray(pl_matmul(a, w)),
        np.asarray(ref.matmul(a, w)),
        rtol=2e-4,
        atol=2e-4,
    )


def test_matmul_exact_on_tile_multiples():
    rng = np.random.default_rng(4)
    a = rng.standard_normal((256, 128)).astype(np.float32)
    w = rng.standard_normal((128, 256)).astype(np.float32)
    assert_allclose(
        np.asarray(pl_matmul(a, w)), np.asarray(ref.matmul(a, w)), rtol=1e-4, atol=1e-4
    )
