"""AOT pipeline tests: lowering produces loadable HLO text with the right
signatures, no elided constants, and a differentiable training step."""

import re

import jax
import numpy as np
import pytest

from compile import aot, model as M


@pytest.mark.parametrize("name", M.MODELS)
def test_lower_model_signature(name):
    text = aot.lower_model(name, 16, 48, 8, use_pallas=True)
    assert text.startswith("HloModule")
    # Weights must be parameters, never elided `{...}` constants.
    assert "{...}" not in text
    flat, _ = jax.tree_util.tree_flatten(M.build_params(name, aot.LAYERS, 8, 8, 8))
    nparams = len(re.findall(r"parameter\(\d+\)", text.split("ENTRY")[-1]))
    assert nparams == 4 + len(flat), f"{name}: entry takes 4 graph args + weights"


def test_lower_train_packs_loss_and_grads():
    text = aot.lower_train("gcn", 16, 48, 8)
    assert text.startswith("HloModule")
    assert "{...}" not in text
    # Output is the packed [1 + P] vector (loss + flat grads).
    p = sum(w.size for w in jax.tree_util.tree_flatten(
        M.build_params("gcn", aot.LAYERS, 8, 8, 8))[0])
    assert f"f32[{1 + p}]" in text


def test_train_step_gradient_is_correct():
    # Finite-difference check of the packed gradient on a tiny problem.
    import jax.numpy as jnp

    n, e, d = 8, 12, 4
    rng = np.random.default_rng(0)
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    deg = np.zeros((n, 1), np.float32)
    np.add.at(deg, (dst, 0), 1.0)
    x = M.init_features(1, n, d)
    target = np.abs(M.init_features(2, n, d)) * 0.1
    params = M.build_params("gcn", 2, d, d, d)
    flat, treedef = jax.tree_util.tree_flatten(params)

    def loss_fn(ws):
        p = jax.tree_util.tree_unflatten(treedef, list(ws))
        out = M.forward("gcn", p, x, src, dst, deg)
        return jnp.mean((out - target) ** 2)

    grads = jax.grad(loss_fn)(flat)
    # Finite difference on one element of W0.
    eps = 1e-3
    w_plus = [w.copy() for w in flat]
    w_plus[0] = w_plus[0].at[0, 0].add(eps) if hasattr(w_plus[0], "at") else w_plus[0]
    wp = [np.array(w) for w in flat]
    wm = [np.array(w) for w in flat]
    wp[0][0, 0] += eps
    wm[0][0, 0] -= eps
    fd = (float(loss_fn(wp)) - float(loss_fn(wm))) / (2 * eps)
    assert abs(fd - float(grads[0][0, 0])) < 1e-4
