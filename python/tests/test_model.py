"""L2 model tests: shapes, cross-path (pallas vs ref) agreement, weight-init
parity with the Rust stack, and numeric-convention pins."""

import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model as M


def graph(rng, n, e):
    src = rng.integers(0, n, size=e).astype(np.int32)
    dst = rng.integers(0, n, size=e).astype(np.int32)
    deg = np.zeros((n, 1), np.float32)
    np.add.at(deg, (dst, 0), 1.0)
    return src, dst, deg


@pytest.mark.parametrize("name", M.MODELS)
def test_forward_shapes(name):
    rng = np.random.default_rng(0)
    n, e, d = 40, 180, 8
    src, dst, deg = graph(rng, n, e)
    x = M.init_features(7, n, d)
    params = M.build_params(name, 2, d, d, d)
    out = M.forward(name, params, x, src, dst, deg)
    assert out.shape == (n, d)
    assert np.all(np.isfinite(np.asarray(out)))


@pytest.mark.parametrize("name", M.MODELS)
def test_pallas_path_matches_ref_path(name):
    rng = np.random.default_rng(1)
    n, e, d = 32, 140, 16
    src, dst, deg = graph(rng, n, e)
    x = M.init_features(3, n, d)
    params = M.build_params(name, 2, d, d, d)
    a = M.forward(name, params, x, src, dst, deg, use_pallas=False)
    b = M.forward(name, params, x, src, dst, deg, use_pallas=True)
    assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_weight_init_matches_rust_pins():
    # Pinned in rust/src/exec/weights.rs::known_values_pinned.
    assert abs(M.weight_elem(42, 0, 0, 16) - (-0.0010140946)) < 1e-7
    assert abs(M.weight_elem(42, 3, 5, 16) - 0.04941747) < 1e-7


def test_weight_init_deterministic():
    a = M.init_weight(5, 8, 8)
    b = M.init_weight(5, 8, 8)
    c = M.init_weight(6, 8, 8)
    assert np.array_equal(a, b)
    assert not np.array_equal(a, c)
    assert np.all((a >= -0.1) & (a < 0.1))


def test_isolated_vertices_conventions():
    # Vertex n-1 has no in-edges: GCN must pass its features through the
    # rsqrt(0)=1 convention; GAT must emit exactly 0 for it.
    n, d = 8, 4
    src = np.array([0, 1, 2], np.int32)
    dst = np.array([1, 2, 3], np.int32)
    deg = np.zeros((n, 1), np.float32)
    np.add.at(deg, (dst, 0), 1.0)
    x = M.init_features(9, n, d)
    out_gat = np.asarray(
        M.forward("gat", M.build_params("gat", 1, d, d, d), x, src, dst, deg)
    )
    assert np.all(out_gat[4:] == 0.0)
    out_gcn = np.asarray(
        M.forward("gcn", M.build_params("gcn", 1, d, d, d), x, src, dst, deg)
    )
    assert np.all(np.isfinite(out_gcn))


def test_model_seed_mirror():
    assert M.model_seed("gcn", 0, 0) == 1_000_000
    assert M.model_seed("ggnn", 1, 7) == 4_001_007
