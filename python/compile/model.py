"""L2: the four Tbl I GNN models in JAX, numerics-identical to the Rust IR
reference (rust/src/exec/reference.rs) and the compiled-ISA executor.

Weight/feature initialisation uses pure 64-bit integer mixing so every
layer of the stack (Rust, JAX, and the AOT'd HLO) sees bit-identical f32
parameters — see rust/src/exec/weights.rs.

`use_pallas=True` routes the gather and matmul hot-spots through the L1
Pallas kernels so they lower into the same HLO at AOT time.
"""

import numpy as np
import jax.numpy as jnp

from .kernels import ref
from .kernels.matmul import matmul as pallas_matmul
from .kernels.seg_reduce import seg_reduce

MASK = (1 << 64) - 1


def _mix(z: int) -> int:
    """splitmix64 finalizer — mirrors rust/src/exec/weights.rs::mix."""
    z = (z + 0x9E3779B97F4A7C15) & MASK
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
    return z ^ (z >> 31)


def weight_elem(seed: int, i: int, j: int, cols: int) -> float:
    h = _mix(seed ^ _mix(i * cols + j + 1))
    unit = (h >> 11) * (1.0 / (1 << 53))
    return np.float32((unit * 2.0 - 1.0) * 0.1)


def init_weight(seed: int, rows: int, cols: int) -> np.ndarray:
    w = np.empty((rows, cols), np.float32)
    for i in range(rows):
        for j in range(cols):
            w[i, j] = weight_elem(seed, i, j, cols)
    return w


def init_features(seed: int, n: int, dim: int) -> np.ndarray:
    x = np.empty((n, dim), np.float32)
    for i in range(n):
        for j in range(dim):
            h = _mix(seed ^ _mix((i * dim + j) ^ 0xFEED))
            unit = (h >> 11) * (1.0 / (1 << 53))
            x[i, j] = np.float32(unit * 2.0 - 1.0)
    return x


def model_seed(model: str, layer: int, which: int) -> int:
    """Mirror of rust/src/ir/models.rs::seed."""
    mid = {"gcn": 1, "gat": 2, "sage": 3, "ggnn": 4}.get(model, 9)
    return mid * 1_000_000 + layer * 1_000 + which


# ---- shared numeric conventions ---------------------------------------------


def rsqrt_deg(deg):
    """rsqrt with the rsqrt(0) := 1 convention (isolated vertices)."""
    return jnp.where(deg > 0, 1.0 / jnp.sqrt(jnp.maximum(deg, 1e-30)), 1.0)


def safe_recip(x):
    """recip(0) := 0 (GAT softmax denominators of isolated vertices)."""
    return jnp.where(x == 0, 0.0, 1.0 / jnp.where(x == 0, 1.0, x))


def leaky_relu(x):
    return jnp.where(x >= 0, x, 0.01 * x)


def _ops(use_pallas: bool):
    if use_pallas:
        return pallas_matmul, seg_reduce

    def _ref_seg(vals, dst, n, reduce="sum"):
        if reduce == "sum":
            return ref.seg_sum(vals, dst, n)
        if reduce == "max":
            return ref.seg_max(vals, dst, n)
        return ref.seg_mean(vals, dst, n)

    return ref.matmul, _ref_seg


# ---- layers ------------------------------------------------------------------


def gcn_layer(x, src, dst, deg, w, *, use_pallas=False):
    mm, seg = _ops(use_pallas)
    n = x.shape[0]
    dn = rsqrt_deg(deg)
    hs = x * dn
    a = seg(hs[src], dst, n, reduce="sum")
    z = mm(a, w)
    return jnp.maximum(z * dn, 0.0)


def gat_layer(x, src, dst, deg, params, *, use_pallas=False):
    mm, seg = _ops(use_pallas)
    del deg
    n = x.shape[0]
    w, al, ar = params
    hw = mm(x, w)
    el = mm(hw, al)  # [N, 1] dst attention term
    er = mm(hw, ar)  # [N, 1] src attention term
    s = leaky_relu(el[dst] + er[src])  # [E, 1]
    m = seg(s, dst, n, reduce="max")
    ex = jnp.exp(s - m[dst])
    den = seg(ex, dst, n, reduce="sum")
    msg = hw[src] * ex
    num = seg(msg, dst, n, reduce="sum")
    a = num * safe_recip(den)
    return jnp.maximum(a, 0.0)


def sage_layer(x, src, dst, deg, params, *, use_pallas=False):
    mm, seg = _ops(use_pallas)
    del deg
    n = x.shape[0]
    wp, b, w = params
    t = mm(x, wp) + b
    a = seg(t[src], dst, n, reduce="max")
    cat = jnp.concatenate([x, a], axis=1)
    return jnp.maximum(mm(cat, w), 0.0)


def ggnn_layer(x, src, dst, deg, params, *, use_pallas=False):
    mm, seg = _ops(use_pallas)
    del deg
    n = x.shape[0]
    w, b, wz, uz, wr, ur, wh, uh = params
    t = mm(x, w) + b
    a = seg(t[src], dst, n, reduce="sum")
    z = 1.0 / (1.0 + jnp.exp(-(mm(a, wz) + mm(x, uz))))
    r = 1.0 / (1.0 + jnp.exp(-(mm(a, wr) + mm(x, ur))))
    hc = jnp.tanh(mm(a, wh) + mm(r * x, uh))
    return (1.0 - z) * x + z * hc


# ---- stacked models ----------------------------------------------------------

MODELS = ("gcn", "gat", "sage", "ggnn")


def _dims(layers, in_dim, hid_dim, out_dim):
    return [
        (
            in_dim if l == 0 else hid_dim,
            out_dim if l == layers - 1 else hid_dim,
        )
        for l in range(layers)
    ]


def build_params(model: str, layers: int, in_dim: int, hid_dim: int, out_dim: int):
    """Materialise all weights for a stacked model, in layer order."""
    params = []
    for l, (di, do) in enumerate(_dims(layers, in_dim, hid_dim, out_dim)):
        if model == "gcn":
            params.append(init_weight(model_seed("gcn", l, 0), di, do))
        elif model == "gat":
            params.append(
                (
                    init_weight(model_seed("gat", l, 0), di, do),
                    init_weight(model_seed("gat", l, 1), do, 1),
                    init_weight(model_seed("gat", l, 2), do, 1),
                )
            )
        elif model == "sage":
            params.append(
                (
                    init_weight(model_seed("sage", l, 0), di, di),
                    init_weight(model_seed("sage", l, 1), 1, di),
                    init_weight(model_seed("sage", l, 2), 2 * di, do),
                )
            )
        elif model == "ggnn":
            params.append(
                tuple(
                    init_weight(model_seed("ggnn", l, k), di, di)
                    if k != 1
                    else init_weight(model_seed("ggnn", l, 1), 1, di)
                    for k in range(8)
                )
            )
        else:
            raise ValueError(model)
    return params


LAYER_FNS = {
    "gcn": gcn_layer,
    "gat": gat_layer,
    "sage": sage_layer,
    "ggnn": ggnn_layer,
}


def forward(model: str, params, x, src, dst, deg, *, use_pallas=False):
    """Stacked forward pass. Returns the `[N, out_dim]` embedding matrix."""
    h = x
    for layer_params in params:
        h = LAYER_FNS[model](h, src, dst, deg, layer_params, use_pallas=use_pallas)
    return h
