"""AOT pipeline: lower each JAX model (with Pallas kernels inlined under
interpret=True) to HLO *text* consumed by the Rust PJRT runtime.

HLO text — NOT `.serialize()` — is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts are shape-specialised: `<model>_n{N}_e{E}_d{D}.hlo.txt` takes
`(x [N,D], src [E] i32, dst [E] i32, deg [N,1])` and returns the 1-tuple
`([N,D],)`. Python runs only here — never on the Rust request path.
"""

import argparse
import functools
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

# Validation-graph default shapes (mirrored in rust/src/runtime/).
DEFAULT_N = 64
DEFAULT_E = 256
DEFAULT_D = 16
LAYERS = 2


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(name: str, n: int, e: int, d: int, use_pallas: bool) -> str:
    """Lower one model. Weights are *arguments*, not closure constants: the
    HLO text writer elides large literals as `{...}`, which would silently
    zero the parameters after the text round-trip. The Rust runtime
    reconstructs the same weights from the shared integer-mixing init and
    passes them positionally (order = build_params order, which mirrors
    the Rust compiler's WeightInfo order)."""
    params = M.build_params(name, LAYERS, d, d, d)
    flat, treedef = jax.tree_util.tree_flatten(params)

    def fn(x, src, dst, deg, *ws):
        # Keep `deg` alive even for models that ignore it so the lowered
        # entry always has the same 4 + num_weights signature (jit DCEs
        # unused parameters otherwise).
        x = x + 0.0 * deg
        p = jax.tree_util.tree_unflatten(treedef, list(ws))
        return (M.forward(name, p, x, src, dst, deg, use_pallas=use_pallas),)

    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((n, d), jnp.float32),
        jax.ShapeDtypeStruct((e,), jnp.int32),
        jax.ShapeDtypeStruct((e,), jnp.int32),
        jax.ShapeDtypeStruct((n, 1), jnp.float32),
        *[jax.ShapeDtypeStruct(w.shape, jnp.float32) for w in flat],
    )
    return to_hlo_text(lowered)


def lower_train(name: str, n: int, e: int, d: int) -> str:
    """Training-step artifact: returns a single `[1 + P]` vector packing
    `[loss, flat_grads...]` so the Rust SGD loop needs only one output
    buffer. Gradients flow through the pure-jnp reference ops (the Pallas
    kernels are forward-path; interpret-mode `pallas_call` has no VJP)."""
    params = M.build_params(name, LAYERS, d, d, d)
    flat, treedef = jax.tree_util.tree_flatten(params)

    def loss_fn(ws, x, src, dst, deg, target):
        p = jax.tree_util.tree_unflatten(treedef, list(ws))
        out = M.forward(name, p, x, src, dst, deg, use_pallas=False)
        return jnp.mean((out - target) ** 2)

    def fn(x, src, dst, deg, target, *ws):
        x = x + 0.0 * deg
        loss, grads = jax.value_and_grad(loss_fn)(list(ws), x, src, dst, deg, target)
        packed = jnp.concatenate(
            [loss[None]] + [g.reshape(-1) for g in grads]
        )
        return (packed,)

    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((n, d), jnp.float32),
        jax.ShapeDtypeStruct((e,), jnp.int32),
        jax.ShapeDtypeStruct((e,), jnp.int32),
        jax.ShapeDtypeStruct((n, 1), jnp.float32),
        jax.ShapeDtypeStruct((n, d), jnp.float32),
        *[jax.ShapeDtypeStruct(w.shape, jnp.float32) for w in flat],
    )
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="single-file mode (Makefile stamp)")
    ap.add_argument("--n", type=int, default=DEFAULT_N)
    ap.add_argument("--e", type=int, default=DEFAULT_E)
    ap.add_argument("--d", type=int, default=DEFAULT_D)
    ap.add_argument(
        "--models", default="gcn,gat,sage,ggnn", help="comma-separated subset"
    )
    ap.add_argument(
        "--no-pallas",
        action="store_true",
        help="lower the pure-jnp reference instead of the Pallas kernels",
    )
    ap.add_argument(
        "--train-models",
        default="gcn",
        help="comma-separated models to emit training-step artifacts for",
    )
    args = ap.parse_args()

    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    os.makedirs(out_dir, exist_ok=True)

    for name in args.models.split(","):
        name = name.strip()
        assert name in M.MODELS, f"unknown model {name}"
        text = lower_model(name, args.n, args.e, args.d, not args.no_pallas)
        path = os.path.join(
            out_dir, f"{name}_n{args.n}_e{args.e}_d{args.d}.hlo.txt"
        )
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)", file=sys.stderr)

    for name in filter(None, (m.strip() for m in args.train_models.split(","))):
        assert name in M.MODELS, f"unknown model {name}"
        text = lower_train(name, args.n, args.e, args.d)
        path = os.path.join(
            out_dir, f"{name}_train_n{args.n}_e{args.e}_d{args.d}.hlo.txt"
        )
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)", file=sys.stderr)

    if args.out:
        # Makefile stamp: a tiny matmul+bias computation for the runtime
        # smoke test / quickstart serving demo.
        def toy(x, y):
            return (jnp.matmul(x, y) + 2.0,)

        spec = jax.ShapeDtypeStruct((8, 8), jnp.float32)
        text = to_hlo_text(jax.jit(toy).lower(spec, spec))
        with open(args.out, "w") as f:
            f.write(text)
        print(f"wrote {args.out} ({len(text)} chars)", file=sys.stderr)


if __name__ == "__main__":
    main()
