"""L1 Pallas kernel: COO segment-reduce — the GatherOp hot-spot of the
accelerator's GatherPhase (paper §V-B1: "each core is responsible for one
destination vertex in GatherOp").

TPU adaptation (DESIGN.md §4): the destination-interval tile stays resident
in VMEM (the accelerator's DstBuffer) while edges stream; feature columns
are tiled so a (interval × feature-tile) block plus the edge stream fits
VMEM. `interpret=True` everywhere — the CPU PJRT plugin cannot execute
Mosaic custom-calls; real-TPU efficiency is assessed structurally
(EXPERIMENTS.md §Perf L1).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Feature-tile width: one VPU lane group (8×128 fp32 VREG layout).
FEATURE_TILE = 128


def _seg_reduce_kernel(dst_ref, vals_ref, out_ref, *, reduce: str, edges: int):
    """One grid step owns a feature tile; edges stream in a fori_loop.

    VMEM residency: `out_ref` (the destination tile) is the accumulator —
    the DSW dual-window guarantees all of a shard's destinations fall in
    the resident interval, so accumulation never leaves VMEM.
    """
    if reduce == "max":
        out_ref[...] = jnp.full_like(out_ref, -jnp.inf)
    else:
        out_ref[...] = jnp.zeros_like(out_ref)

    def body(e, _):
        d = dst_ref[e]
        row = vals_ref[e, :]
        cur = pl.load(out_ref, (d, slice(None)))
        new = jnp.maximum(cur, row) if reduce == "max" else cur + row
        pl.store(out_ref, (d, slice(None)), new)
        return 0

    jax.lax.fori_loop(0, edges, body, 0)


@functools.partial(jax.jit, static_argnames=("num_vertices", "reduce"))
def seg_reduce(edge_vals, dst, num_vertices, reduce="sum"):
    """Segment-reduce `edge_vals [E, D]` by `dst [E]` into `[N, D]`.

    `reduce` ∈ {"sum", "max", "mean"}; empty rows produce 0 (the
    convention shared with the Rust stack and ref.py).
    """
    e, d = edge_vals.shape
    base = "max" if reduce == "max" else "sum"
    grid = (max(1, (d + FEATURE_TILE - 1) // FEATURE_TILE),)
    tile = min(d, FEATURE_TILE)
    out = pl.pallas_call(
        functools.partial(_seg_reduce_kernel, reduce=base, edges=e),
        grid=grid,
        in_specs=[
            pl.BlockSpec((e,), lambda i: (0,)),  # dst ids: replicated per tile
            pl.BlockSpec((e, tile), lambda i: (0, i)),  # edge-value tile
        ],
        out_specs=pl.BlockSpec((num_vertices, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((num_vertices, d), edge_vals.dtype),
        interpret=True,
    )(dst, edge_vals)

    count = jnp.zeros((num_vertices,), jnp.int32).at[dst].add(1)
    if reduce == "max":
        return jnp.where((count > 0)[:, None], out, 0.0)
    if reduce == "mean":
        return out / jnp.maximum(count, 1).astype(out.dtype)[:, None]
    return out
