"""Pure-jnp oracles for the Pallas kernels (the CORE kernel correctness
signal: pytest asserts kernel == ref over shape/dtype sweeps).

Conventions shared with the Rust executor/reference (rust/src/exec/):
  * gather over an empty in-edge set yields zeros (also for max),
  * rsqrt(0) := 1, recip(0) := 0.
"""

import jax.numpy as jnp


def seg_sum(edge_vals, dst, num_vertices):
    """Segment sum of edge rows by destination: out[v] = sum over e with dst[e]=v."""
    return jnp.zeros((num_vertices, edge_vals.shape[1]), edge_vals.dtype).at[dst].add(
        edge_vals
    )


def seg_max(edge_vals, dst, num_vertices):
    """Segment max; vertices with no in-edges get 0 (shared convention)."""
    neg = jnp.full((num_vertices, edge_vals.shape[1]), -jnp.inf, edge_vals.dtype)
    m = neg.at[dst].max(edge_vals)
    count = jnp.zeros((num_vertices,), jnp.int32).at[dst].add(1)
    return jnp.where((count > 0)[:, None], m, 0.0)


def seg_mean(edge_vals, dst, num_vertices):
    """Segment mean; empty rows are 0."""
    s = seg_sum(edge_vals, dst, num_vertices)
    count = jnp.zeros((num_vertices,), jnp.int32).at[dst].add(1)
    denom = jnp.maximum(count, 1).astype(edge_vals.dtype)
    return s / denom[:, None]


def matmul(a, w):
    """Dense matmul oracle (fp32 accumulation)."""
    return jnp.dot(a, w, preferred_element_type=jnp.float32)


def gather_rows(x, idx):
    """Row gather: x[idx] — the ScatterOp of the paper (vertex-to-edge copy)."""
    return x[idx]
