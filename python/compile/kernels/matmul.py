"""L1 Pallas kernel: VMEM-tiled matmul — the DMM hot-spot executed by the
accelerator's MU (32×128 output-stationary systolic array).

TPU adaptation: 128×128 output tiles (MXU-shaped), K-innermost
accumulation — the same output-stationary dataflow as the paper's MU. The
BlockSpec index maps express the HBM↔VMEM schedule the accelerator's LSU
performs with its prefetch flag. `interpret=True` for CPU-PJRT execution.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 128


def _matmul_kernel(a_ref, w_ref, out_ref, *, k_steps: int):
    """Output-stationary: the out tile accumulates over the K grid axis."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.dot(
        a_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _pad_to(x, rows, cols):
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr or pc:
        x = jnp.pad(x, ((0, pr), (0, pc)))
    return x


@jax.jit
def matmul(a, w):
    """`a [M, K] × w [K, N] → [M, N]` with 128×128×128 VMEM tiles.

    Shapes are padded up to tile multiples (the accelerator's MU pads rows
    the same way; macro row counts V/S/E are runtime values).
    """
    m, k = a.shape
    k2, n = w.shape
    assert k == k2, f"matmul shape mismatch {a.shape} x {w.shape}"
    tm, tk, tn = min(TILE, m), min(TILE, k), min(TILE, n)
    gm = (m + tm - 1) // tm
    gk = (k + tk - 1) // tk
    gn = (n + tn - 1) // tn
    a_p = _pad_to(a, gm * tm, gk * tk)
    w_p = _pad_to(w, gk * tk, gn * tn)
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=gk),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gm * tm, gn * tn), jnp.float32),
        interpret=True,
    )(a_p, w_p)
    return out[:m, :n]
