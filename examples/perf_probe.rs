//! Perf probe: stage-by-stage timing of the hot path (graph generation →
//! CSR indexing → partitioning → simulation) on the largest workload.
//! Drives the EXPERIMENTS.md §Perf iteration log.

use std::time::Instant;
use switchblade::compiler::compile;
use switchblade::graph::datasets::Dataset;
use switchblade::graph::Csr;
use switchblade::ir::models::Model;
use switchblade::partition::{partition_fggp, partition_dsw};
use switchblade::sim::{simulate, AcceleratorConfig};

fn main() {
    let scale: u32 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(3);
    let t0 = Instant::now();
    let el = Dataset::Sl.generate(scale);
    let t_gen = t0.elapsed();
    let t0 = Instant::now();
    let g = Csr::from_edge_list(&el);
    let t_csr = t0.elapsed();
    let prog = compile(&Model::Gcn.build_paper());
    let accel = AcceleratorConfig::switchblade();
    let pc = accel.partition_config(&prog);
    let t0 = Instant::now();
    let parts = partition_fggp(&g, pc);
    let t_fggp = t0.elapsed();
    let t0 = Instant::now();
    let parts_d = partition_dsw(&g, pc);
    let t_dsw = t0.elapsed();
    let t0 = Instant::now();
    let r = simulate(&prog, &parts, &accel);
    let t_sim = t0.elapsed();
    println!("scale={scale} |V|={} |E|={}", g.num_vertices(), g.num_edges());
    println!("generate   {t_gen:?}");
    println!("csr build  {t_csr:?}");
    println!("fggp       {t_fggp:?} ({} shards)", parts.shards.len());
    println!("dsw        {t_dsw:?} ({} shards)", parts_d.shards.len());
    println!("simulate   {t_sim:?} ({:.1} M simulated cycles, {:.1} Mcyc/s)",
        r.cycles / 1e6, r.cycles / 1e6 / t_sim.as_secs_f64());
}
