//! Quickstart: the whole SWITCHBLADE pipeline on one small workload.
//!
//!   cargo run --release --example quickstart
//!
//! 1. Build the GCN model IR (Tbl I row 1) and compile it to PLOF phases.
//! 2. Generate the ak2010 stand-in graph and partition it with FGGP.
//! 3. Simulate the accelerator and print latency/utilisation/traffic.
//! 4. Cross-check the numerics of the compiled program against the IR
//!    reference oracle.

use switchblade::compiler::compile;
use switchblade::coordinator::validate_numerics;
use switchblade::graph::datasets::Dataset;
use switchblade::ir::spec::ModelDims;
use switchblade::ir::zoo::ModelZoo;
use switchblade::partition::{partition_fggp, stats};
use switchblade::sim::{simulate, AcceleratorConfig};

fn main() {
    // 1. Compile (the zoo's GCN spec at its default paper shape).
    let gcn = ModelZoo::builtin().get("gcn").expect("builtin gcn");
    let ir = gcn.graph();
    let prog = compile(&ir);
    println!("compiled {}: {} groups, {} instructions, dim_src={}, dim_edge={}",
        prog.model_name, prog.groups.len(), prog.num_instrs(), prog.dim_src, prog.dim_edge);

    // 2. Partition.
    let g = Dataset::Ak.load(2);
    let accel = AcceleratorConfig::switchblade();
    let parts = partition_fggp(&g, accel.partition_config(&prog));
    parts.validate().expect("valid partitioning");
    let st = stats::analyze(&parts);
    println!("partitioned ak2010 ({} vertices, {} edges): {} intervals, {} shards, occupancy {:.1}%",
        g.num_vertices(), g.num_edges(), st.num_intervals, st.num_shards,
        100.0 * st.occupancy_rate);

    // 3. Simulate.
    let r = simulate(&prog, &parts, &accel);
    println!("simulated: {:.0} cycles ({:.3} ms @ 1 GHz), overall utilisation {:.1}%, DRAM {:.1} MB",
        r.cycles, r.seconds * 1e3, 100.0 * r.overall_utilization(),
        r.traffic.total() as f64 / 1e6);

    // 4. Validate numerics (small shape keeps the dense oracle fast).
    let small = gcn.build(ModelDims::uniform(2, 16)).expect("gcn at 16-dim");
    let diff = validate_numerics(&small, &g, &accel);
    println!("numerics vs oracle: max |delta| = {diff:.2e}");
    assert!(diff < 1e-4);
    println!("quickstart OK");
}
