//! Training end-to-end: the AOT-lowered backward pass (jax.value_and_grad
//! over the L2 model, HLO-text interchange) driven by a Rust SGD loop via
//! PJRT — Python never runs at training time.
//!
//!   make artifacts && cargo run --release --example training

use switchblade::exec::{weights, Matrix};
use switchblade::graph::Csr;
use switchblade::runtime::{artifacts_dir, ArtifactShape, Runtime};

fn main() {
    let shape = ArtifactShape::default();
    let dir = artifacts_dir();
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            println!("(skipping training demo: {e:#})");
            return;
        }
    };
    let mut trainer = rt
        .load_trainer(&dir, "gcn", shape, 50.0)
        .expect("load gcn training artifact (run `make artifacts`)");

    // Fixed synthetic regression task on the validation graph.
    let el = switchblade::graph::generators::rmat(shape.n, shape.e, 0.57, 0.19, 0.19, 99);
    let g = Csr::from_edge_list(&el);
    let mut src = vec![0i32; shape.e];
    let mut dst = vec![0i32; shape.e];
    for (s, d, id) in g.edges_canonical() {
        src[id as usize] = s as i32;
        dst[id as usize] = d as i32;
    }
    let deg: Vec<f32> = (0..shape.n).map(|v| g.in_degree(v as u32) as f32).collect();
    let x = weights::init_features(7, shape.n, shape.d);
    // Realisable teacher target: 2x the initial model's own output — the
    // student only needs to rescale its head, so SGD can drive the loss
    // toward zero instead of a capacity plateau.
    let ir = switchblade::ir::models::Model::Gcn.build(2, 16, 16, 16);
    let mut target = switchblade::exec::reference::evaluate(&ir, &g, &x);
    for v in &mut target.data {
        *v *= 2.0;
    }

    println!("training 2-layer GCN ({} weights) with Rust SGD @ lr=50.0", trainer.weights.len());
    let mut first = None;
    let mut last = 0.0;
    for step in 0..200 {
        let loss = trainer.step(&x, &src, &dst, &deg, &target).expect("step");
        first.get_or_insert(loss);
        last = loss;
        if step % 40 == 0 {
            println!("step {step:3}  loss {loss:.3e}");
        }
    }
    let first = first.unwrap();
    println!("step 200  loss {last:.3e}  ({}x reduction)", (first / last) as u32);
    assert!(last < first * 0.5, "loss must decrease: {first} -> {last}");
    println!("training OK");
}
