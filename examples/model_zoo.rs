//! Model variety demo — the paper's central claim is *generality*: the
//! same compiler/partitioner/accelerator run all four Tbl I models with
//! no model-specific hardware.
//!
//!   cargo run --release --example model_zoo

use switchblade::compiler::compile;
use switchblade::coordinator::Caches;
use switchblade::graph::datasets::Dataset;
use switchblade::ir::models::Model;
use switchblade::partition::partition_fggp;
use switchblade::sim::{simulate, AcceleratorConfig};
use switchblade::util::report::{f, Table};

fn main() {
    let cache = Caches::new(4);
    let g = cache.graph(Dataset::Ad);
    let accel = AcceleratorConfig::switchblade();
    let mut t = Table::new(
        "model zoo on coAuthorsDBLP",
        &["model", "groups", "instrs", "dim_src", "dim_edge", "cycles", "util", "MB moved"],
    );
    for m in Model::ALL {
        let prog = compile(&m.build_paper());
        let parts = partition_fggp(&g, accel.partition_config(&prog));
        let r = simulate(&prog, &parts, &accel);
        t.row(vec![
            m.name().into(),
            prog.groups.len().to_string(),
            prog.num_instrs().to_string(),
            prog.dim_src.to_string(),
            prog.dim_edge.to_string(),
            format!("{:.0}", r.cycles),
            f(r.overall_utilization(), 2),
            f(r.traffic.total() as f64 / 1e6, 1),
        ]);
    }
    t.print();
    println!("\nThe same ISA/hardware executed GCN (2 ops/layer) through GGNN (20+ ops/layer).");
}
