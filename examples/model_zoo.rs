//! Model variety demo — the paper's central claim is *generality*: the
//! same compiler/partitioner/accelerator run every model in the zoo with
//! no model-specific hardware. The zoo is open: the built-in entries are
//! `.gnn` specs (node-for-node identical to the legacy Rust builders),
//! and any user spec file joins the same pipeline — here a GIN defined
//! purely in `examples/models/gin.gnn`, with zero Rust changes.
//!
//!   cargo run --release --example model_zoo

use switchblade::compiler::compile;
use switchblade::coordinator::Caches;
use switchblade::graph::datasets::Dataset;
use switchblade::ir::spec::ModelSpec;
use switchblade::ir::zoo::ModelZoo;
use switchblade::partition::partition_fggp;
use switchblade::sim::{simulate, AcceleratorConfig};
use switchblade::util::report::{f, Table};

fn main() {
    let cache = Caches::new(4);
    let g = cache.graph(Dataset::Ad);
    let accel = AcceleratorConfig::switchblade();

    // Built-in zoo entries plus two spec files shipped with the repo.
    let mut specs = ModelZoo::builtin().entries().to_vec();
    for src in [
        include_str!("models/gin.gnn"),
        include_str!("models/gcn3.gnn"),
    ] {
        specs.push(std::sync::Arc::new(
            ModelSpec::parse("file", src).expect("example spec"),
        ));
    }

    let mut t = Table::new(
        "model zoo on coAuthorsDBLP",
        &["model", "dims", "groups", "instrs", "dim_src", "dim_edge", "cycles", "util", "MB moved"],
    );
    for m in &specs {
        let prog = compile(&m.graph());
        let parts = partition_fggp(&g, accel.partition_config(&prog));
        let r = simulate(&prog, &parts, &accel);
        t.row(vec![
            m.display(),
            format!("{}", m.dims()),
            prog.groups.len().to_string(),
            prog.num_instrs().to_string(),
            prog.dim_src.to_string(),
            prog.dim_edge.to_string(),
            format!("{:.0}", r.cycles),
            f(r.overall_utilization(), 2),
            f(r.traffic.total() as f64 / 1e6, 1),
        ]);
    }
    t.print();
    println!(
        "\nThe same ISA/hardware executed GCN (2 ops/layer) through GGNN (20+ ops/layer) —\n\
         plus GIN and a 3-layer GCN defined purely in .gnn spec files."
    );
}
