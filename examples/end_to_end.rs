//! End-to-end driver (the EXPERIMENTS.md headline run): exercises every
//! layer of the stack on a real small workload —
//!
//! 1. **L1/L2 via PJRT**: load the AOT-compiled JAX models (Pallas kernels
//!    inlined) and run them on a real graph through the Rust runtime;
//!    check them against the Rust IR oracle AND the compiled-ISA
//!    executor (three-way numerics).
//! 2. **L3**: run the full 4-model × 5-dataset evaluation sweep and print
//!    the paper's headline metric (Fig 7 speedup + Fig 8 energy).
//!
//!   make artifacts && cargo run --release --example end_to_end

use switchblade::compiler::compile;
use switchblade::coordinator::{Caches, Harness};
use switchblade::exec::{reference, weights, Executor, Matrix};
use switchblade::graph::Csr;
use switchblade::ir::models::Model;
use switchblade::partition::partition_fggp;
use switchblade::runtime::{artifacts_dir, ArtifactShape, Runtime};
use switchblade::sim::AcceleratorConfig;

fn main() {
    // ---- Part 1: numerics through the real PJRT runtime -------------------
    let shape = ArtifactShape::default();
    let dir = artifacts_dir();
    let rt = if dir.join(shape.file_name("gcn")).exists() {
        Runtime::cpu()
            .map_err(|e| println!("(skipping PJRT check: {e:#})\n"))
            .ok()
    } else {
        println!("(artifacts missing — run `make artifacts` for the PJRT check)\n");
        None
    };
    if let Some(rt) = rt {
        println!("PJRT platform: {}", rt.platform());
        let el = switchblade::graph::generators::rmat(shape.n, shape.e, 0.57, 0.19, 0.19, 99);
        let g = Csr::from_edge_list(&el);
        let mut src = vec![0i32; shape.e];
        let mut dst = vec![0i32; shape.e];
        for (s, d, id) in g.edges_canonical() {
            src[id as usize] = s as i32;
            dst[id as usize] = d as i32;
        }
        let deg: Vec<f32> = (0..shape.n).map(|v| g.in_degree(v as u32) as f32).collect();
        let x = weights::init_features(7, shape.n, shape.d);
        for m in Model::ALL {
            let name = m.name().to_lowercase();
            let exe = rt.load_model(&dir, &name, shape).expect("load model");
            let got = exe.run(&x, &src, &dst, &deg).expect("pjrt run");
            let ir = m.build(2, shape.d as u32, shape.d as u32, shape.d as u32);
            let want = reference::evaluate(&ir, &g, &x);
            let prog = compile(&ir);
            let accel = AcceleratorConfig::switchblade();
            let parts = partition_fggp(&g, accel.partition_config(&prog));
            let deg_m = Matrix::from_vec(shape.n, 1, deg.clone());
            let isa_out = Executor::new(&prog, &parts).run(&x, &deg_m);
            println!(
                "{:5}  PJRT vs oracle: {:.2e}   ISA vs PJRT: {:.2e}",
                m.name(),
                got.max_abs_diff(&want),
                isa_out.max_abs_diff(&got)
            );
            assert!(got.allclose(&want, 1e-3, 1e-4));
            assert!(isa_out.allclose(&got, 1e-3, 1e-4));
        }
        println!("three-way numerics agreement: OK\n");
    }

    // ---- Part 2: the paper's headline metric -------------------------------
    let h = Harness { scale: 7, ..Default::default() };
    let cache = Caches::new(h.scale);
    println!("running the 4x5 evaluation sweep (scale 1/2^7)...");
    let rows = h.eval_all(&cache);
    h.fig07(&rows).print();
    println!();
    h.fig08(&rows).print();
    println!("\npaper headline: 1.85x speedup / 19.03x energy saving vs V100.");
}
