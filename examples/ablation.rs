//! Ablation driver for the three proposed methods (DESIGN.md §5):
//!
//! * FGGP vs DSW partitioning (same budgets),
//! * SLMT on (3 sThreads) vs off (1),
//! * PLOF instruction fusion is structural (always on) — its effect is
//!   shown through the edge-traffic column (dim_edge = 0 for GCN).
//!
//!   cargo run --release --example ablation

use switchblade::compiler::{compile, compile_with, CompilerOptions};
use switchblade::coordinator::Caches;
use switchblade::graph::datasets::Dataset;
use switchblade::ir::models::Model;
use switchblade::partition::{partition_dsw, partition_fggp};
use switchblade::sim::{simulate, AcceleratorConfig};
use switchblade::util::report::{f, Table};

fn main() {
    let cache = Caches::new(7);
    let g = cache.graph(Dataset::Sl);
    let prog = compile(&Model::Gcn.build_paper());
    let mut t = Table::new(
        "GCN on soc-LiveJournal: method ablation",
        &["config", "cycles", "norm", "traffic MB", "overall util"],
    );
    let mut base = None;
    for (name, fggp, threads) in [
        ("FGGP + SLMT(3)  [paper]", true, 3u32),
        ("FGGP + SLMT(1)  [no SLMT]", true, 1),
        ("DSW  + SLMT(3)  [no FGGP]", false, 3),
        ("DSW  + SLMT(1)  [neither]", false, 1),
    ] {
        let accel = AcceleratorConfig::switchblade().with_sthreads(threads);
        let pc = accel.partition_config(&prog);
        let parts = if fggp { partition_fggp(&g, pc) } else { partition_dsw(&g, pc) };
        let r = simulate(&prog, &parts, &accel);
        let b = *base.get_or_insert(r.cycles);
        t.row(vec![
            name.into(),
            format!("{:.0}", r.cycles),
            f(r.cycles / b, 3),
            f(r.traffic.total() as f64 / 1e6, 1),
            f(r.overall_utilization(), 2),
        ]);
    }
    t.print();

    // Instruction-level ablations: PLOF peephole fusion and the prologue
    // projection sweep (GAT exercises both).
    let mut t2 = Table::new(
        "GAT on soc-LiveJournal: compiler ablation (3 sThreads, FGGP)",
        &["config", "dim_edge", "cycles", "norm", "traffic MB"],
    );
    let gat = Model::Gat.build_paper();
    let accel = AcceleratorConfig::switchblade();
    let mut base = None;
    for (name, fuse, pro) in [
        ("fusion + prologue  [default]", true, true),
        ("no fusion", false, true),
        ("no prologue", true, false),
        ("neither", false, false),
    ] {
        let prog = compile_with(&gat, CompilerOptions { fuse_gathers: fuse, prologue: pro });
        let parts = partition_fggp(&g, accel.partition_config(&prog));
        let r = simulate(&prog, &parts, &accel);
        let b = *base.get_or_insert(r.cycles);
        t2.row(vec![
            name.into(),
            prog.dim_edge.to_string(),
            format!("{:.0}", r.cycles),
            f(r.cycles / b, 3),
            f(r.traffic.total() as f64 / 1e6, 1),
        ]);
    }
    t2.print();
}
